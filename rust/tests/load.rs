//! Open-loop load engine, end to end: arrival-generator determinism and
//! distribution properties, ramp-run determinism across queue engines,
//! report round-trips, and the acceptance cell — a chaos-composed ramp
//! must knee measurably earlier than its quiet twin.

use houtu::config::{Config, Deployment};
use houtu::dag::{SizeClass, WorkloadKind};
use houtu::ids::DcId;
use houtu::load::{
    arrivals, run_load_on, smoke_spec, write_and_verify, ArrivalProcess, ClassSpec, LoadSpec,
    RampSpec, SloSpec,
};
use houtu::scenario::ChaosEvent;
use houtu::sim::QueueKind;
use houtu::testkit::forall_cases;
use houtu::util::Pcg;

/// A deliberately tiny ramp (~29 expected arrivals, 1920 s horizon):
/// three 240 s steps at 0.02/0.04/0.06 jobs/s of small wordcounts over
/// the default 4-DC topology, with a drain window long enough that every
/// quiet-run job lands well inside the generous SLO.
fn micro_spec() -> LoadSpec {
    LoadSpec {
        name: "micro".to_string(),
        deployment: Deployment::Houtu,
        classes: vec![ClassSpec {
            name: "wc".to_string(),
            kind: WorkloadKind::WordCount,
            size: SizeClass::Small,
            weight: 1.0,
            home: None,
            arrival: ArrivalProcess::Poisson,
        }],
        ramp: RampSpec {
            initial_rps: 0.02,
            increment_rps: 0.02,
            step_secs: 240.0,
            max_rps: 0.06,
            drain_secs: 1200.0,
        },
        slo: SloSpec { p99_secs: 900.0, goodput_frac: 0.6 },
        events: vec![],
        overrides: vec![],
    }
}

/// The shipped example spec parses, validates, and builds a config at
/// the default seed — edits to `configs/load.toml` can't silently rot.
#[test]
fn shipped_load_toml_parses_and_builds() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/load.toml");
    let spec = LoadSpec::from_file(path).unwrap();
    assert_eq!(spec.name, "knee-hunt");
    assert_eq!(spec.classes.len(), 3);
    assert_eq!(spec.step_rates().len(), 6);
    assert_eq!(spec.events.len(), 1);
    spec.build_config(&Config::default(), 1).unwrap();
    let sched = arrivals(&spec, 1, 4);
    // ~189 expected arrivals; 5σ ≈ 69.
    assert!(
        (120..=260).contains(&sched.len()),
        "shipped ramp scheduled {} arrivals",
        sched.len()
    );
}

/// Same spec + same seed ⇒ the *entire* outcome is bit-identical: trace
/// digest, per-step stats, knee, event count. A different seed moves the
/// digest (the stream really is seeded).
#[test]
fn load_run_is_deterministic_per_seed() {
    let base = Config::default();
    let spec = micro_spec();
    let a = run_load_on(&base, &spec, 7, QueueKind::Slab).unwrap();
    let b = run_load_on(&base, &spec, 7, QueueKind::Slab).unwrap();
    assert_eq!(a, b, "same spec+seed must reproduce the full outcome");
    assert!(a.arrivals > 0, "micro ramp must schedule work");
    assert_eq!(a.steps.len(), 3);
    let c = run_load_on(&base, &spec, 8, QueueKind::Slab).unwrap();
    assert_ne!(a.digest, c.digest, "a different seed must move the digest");
}

/// The digest-pinned outcome is queue-engine invariant: slab vs sharded
/// (any shard count) executes the same event stream bit-for-bit, so the
/// digest, the per-step table and the knee all match. This is the
/// in-process half of the ci.sh `load --smoke --shards 4` gate.
#[test]
fn load_outcome_is_engine_invariant() {
    let base = Config::default();
    let spec = micro_spec();
    let slab = run_load_on(&base, &spec, 7, QueueKind::Slab).unwrap();
    for shards in [2usize, 4] {
        let sharded = run_load_on(&base, &spec, 7, QueueKind::Sharded(shards)).unwrap();
        assert_eq!(slab.digest, sharded.digest, "digest diverged at {shards} shards");
        assert_eq!(slab.steps, sharded.steps, "step table diverged at {shards} shards");
        assert_eq!(slab.knee, sharded.knee, "knee diverged at {shards} shards");
        assert_eq!(slab.completed, sharded.completed);
    }
}

/// A fast mixed-size cell (Medium wordcounts + Large pageranks, ~17
/// expected arrivals): Medium/Large coverage that is cheap enough for
/// CI. The knee verdict — whatever it is — must be bit-deterministic
/// and queue-engine invariant, so the heavier job shapes cannot hide an
/// engine-sensitive code path that the all-Small micro ramp never
/// exercises.
fn mixed_spec() -> LoadSpec {
    LoadSpec {
        name: "mixed".to_string(),
        deployment: Deployment::Houtu,
        classes: vec![
            ClassSpec {
                name: "wc-med".to_string(),
                kind: WorkloadKind::WordCount,
                size: SizeClass::Medium,
                weight: 3.0,
                home: None,
                arrival: ArrivalProcess::Poisson,
            },
            ClassSpec {
                name: "pr-large".to_string(),
                kind: WorkloadKind::PageRank,
                size: SizeClass::Large,
                weight: 1.0,
                home: Some(DcId(1)),
                arrival: ArrivalProcess::Poisson,
            },
        ],
        ramp: RampSpec {
            initial_rps: 0.01,
            increment_rps: 0.01,
            step_secs: 300.0,
            max_rps: 0.03,
            drain_secs: 2400.0,
        },
        slo: SloSpec { p99_secs: 1800.0, goodput_frac: 0.6 },
        events: vec![],
        overrides: vec![],
    }
}

/// Medium/Large knee determinism across engines (the CI-gated half of
/// the long-horizon coverage): the mixed cell's digest, step table and
/// knee verdict are identical on the slab queue and the sharded queue
/// at 2 and 4 shards, and reruns replay in lockstep.
#[test]
fn mixed_size_cell_pins_knee_across_engines() {
    let base = Config::default();
    let spec = mixed_spec();
    let a = run_load_on(&base, &spec, 7, QueueKind::Slab).unwrap();
    let b = run_load_on(&base, &spec, 7, QueueKind::Slab).unwrap();
    assert_eq!(a, b, "mixed cell must replay in lockstep");
    assert!(a.arrivals > 0, "mixed ramp must schedule work");
    assert!(a.completed > 0, "mixed ramp must complete jobs");
    for shards in [2usize, 4] {
        let s = run_load_on(&base, &spec, 7, QueueKind::Sharded(shards)).unwrap();
        assert_eq!(a.digest, s.digest, "mixed digest diverged at {shards} shards");
        assert_eq!(a.steps, s.steps, "mixed step table diverged at {shards} shards");
        assert_eq!(a.knee, s.knee, "mixed knee verdict diverged at {shards} shards");
        assert_eq!(a.completed, s.completed);
    }
}

/// Long-horizon Medium/Large ramp (ignored by default — several ramp
/// steps of heavyweight jobs; run with `cargo test --test load --
/// --ignored`): push the mixed classes to 0.2 jobs/s over 8 steps. The
/// heavy tail must saturate the 64-container estate (a knee verdict
/// with a reason), and the whole long-horizon outcome must stay
/// bit-deterministic and engine-invariant — the guarantee CI samples
/// with the fast cell above, proven here at depth.
#[test]
#[ignore = "long-horizon ramp; run with --ignored"]
fn long_horizon_medium_large_ramp_knees_deterministically() {
    let base = Config::default();
    let mut spec = mixed_spec();
    spec.name = "mixed-long".to_string();
    spec.ramp = RampSpec {
        initial_rps: 0.025,
        increment_rps: 0.025,
        step_secs: 600.0,
        max_rps: 0.2,
        drain_secs: 3600.0,
    };
    spec.slo = SloSpec { p99_secs: 900.0, goodput_frac: 0.6 };
    let a = run_load_on(&base, &spec, 7, QueueKind::Slab).unwrap();
    let b = run_load_on(&base, &spec, 7, QueueKind::Slab).unwrap();
    assert_eq!(a, b, "long ramp must replay in lockstep");
    assert_eq!(a.steps.len(), 8, "0.025..0.2 by 0.025 is 8 steps");
    let knee = a.knee.as_ref().expect("0.2 rps of Medium/Large must saturate 64 containers");
    assert!(!knee.reason.is_empty(), "knee verdict must carry a reason");
    let sharded = run_load_on(&base, &spec, 7, QueueKind::Sharded(4)).unwrap();
    assert_eq!(a.digest, sharded.digest, "long-ramp digest diverged at 4 shards");
    assert_eq!(a.knee, sharded.knee, "long-ramp knee diverged at 4 shards");
}

/// The generator is a pure function of (spec, seed, topology): repeated
/// calls are bit-identical, the schedule is time-sorted inside the ramp
/// window, and reseeding moves it.
#[test]
fn arrival_stream_is_pure_sorted_and_seed_sensitive() {
    let spec = smoke_spec();
    let a = arrivals(&spec, 42, 4);
    let b = arrivals(&spec, 42, 4);
    assert_eq!(a, b, "same (spec, seed, dcs) must regenerate the identical stream");
    assert!(!a.is_empty(), "smoke ramp must schedule arrivals");
    let end = spec.ramp_end_secs();
    for w in a.windows(2) {
        assert!(w[0].at_secs <= w[1].at_secs, "schedule must be time-sorted");
    }
    for x in &a {
        assert!(x.at_secs >= 0.0 && x.at_secs < end, "arrival at {} outside ramp", x.at_secs);
        if let Some(home) = x.home {
            assert!(home.0 < 4, "fixed home must fit the topology");
        }
    }
    let c = arrivals(&spec, 43, 4);
    assert_ne!(a, c, "a different seed must move the schedule");
}

/// Distribution property (satellite: generator statistics): a one-step
/// Poisson-only ramp at rate λ over a T-second window yields ≈ λT
/// arrivals with mean inter-arrival ≈ 1/λ. Bounds are ~5σ, so a red run
/// means a broken generator, not an unlucky seed; the failing (rate,
/// seed) case is printed by the kit.
#[test]
fn poisson_interarrival_mean_matches_rate() {
    let gen = |rng: &mut Pcg| (rng.uniform(1.0, 3.0), rng.below(1 << 40));
    forall_cases(11, 24, &gen, |&(rate, seed): &(f64, u64)| {
        let t = 600.0;
        let spec = LoadSpec {
            ramp: RampSpec {
                initial_rps: rate,
                increment_rps: rate,
                step_secs: t,
                max_rps: rate,
                drain_secs: 0.0,
            },
            ..micro_spec()
        };
        let sched = arrivals(&spec, seed, 4);
        let n = sched.len() as f64;
        let expect = rate * t;
        let tol = 5.0 * expect.sqrt() + 1.0;
        if (n - expect).abs() > tol {
            return Err(format!("count {n} vs λT {expect:.0} (tol {tol:.0})"));
        }
        let gaps: Vec<f64> = sched.windows(2).map(|w| w[1].at_secs - w[0].at_secs).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let want = 1.0 / rate;
        // ≥ ~600 samples ⇒ the sample mean sits within 5/√n ≈ 20% of
        // 1/λ at 5σ; 25% leaves margin for window-truncation bias.
        if (mean - want).abs() > 0.25 * want {
            return Err(format!("mean gap {mean:.3}s vs 1/λ {want:.3}s"));
        }
        Ok(())
    });
}

/// JSON and CSV exports round-trip through the same write-then-reparse
/// verification the CLI `--report` path runs, and the rendered table
/// carries the greppable knee verdict.
#[test]
fn load_report_round_trips_json_and_csv() {
    let out = run_load_on(&Config::default(), &smoke_spec(), 42, QueueKind::Slab).unwrap();
    let dir = std::env::temp_dir();
    let json_path = dir.join("houtu_load_report_test.json");
    let csv_path = dir.join("houtu_load_report_test.csv");
    assert_eq!(write_and_verify(&out, json_path.to_str().unwrap()).unwrap(), "json");
    assert_eq!(write_and_verify(&out, csv_path.to_str().unwrap()).unwrap(), "csv");
    let rendered = out.render();
    assert!(rendered.contains("knee:"), "render must carry the knee verdict:\n{rendered}");
    assert!(rendered.contains(&format!("{:016x}", out.digest)), "render must carry the digest");
    // The smoke ramp is sized far from saturation: the ci.sh gate pins
    // its (deterministic) verdict as knee-free.
    assert!(out.knee.is_none(), "smoke ramp must hold its generous SLO: {:?}", out.knee);
    assert!(out.completed > 0, "smoke ramp must complete jobs");
}

/// Acceptance cell: the same micro ramp composed with chaos — container
/// hogs pinning DCs 1–3 from t≈0 (the Fig-9 resource-tense injection;
/// spread-home jobs homed there can never spawn a JM, which is
/// starvation by construction) plus a `spot_storm@` window — must knee,
/// and measurably earlier than the quiet twin, which must not knee at
/// all. Both cells share one arrival schedule (the generator never looks
/// at the chaos plan), so the comparison isolates the injected stress.
#[test]
fn chaos_composed_ramp_knees_earlier_than_quiet() {
    let base = Config::default();
    let quiet = micro_spec();
    let mut chaos = micro_spec();
    chaos.name = "micro-chaos".to_string();
    chaos.events = vec![
        ChaosEvent::InjectHogs {
            at_secs: 1.0,
            dcs: vec![DcId(1), DcId(2), DcId(3)],
        },
        ChaosEvent::SpotStorm { at_secs: 1.0, dc: DcId(0), dur_secs: 600.0, sigma_factor: 4.0 },
    ];
    let q = run_load_on(&base, &quiet, 7, QueueKind::Slab).unwrap();
    let c = run_load_on(&base, &chaos, 7, QueueKind::Slab).unwrap();
    assert_eq!(q.arrivals, c.arrivals, "chaos must not perturb the arrival schedule");
    assert!(
        q.knee.is_none(),
        "quiet micro ramp (≤0.06 rps of smalls on 64 containers) must hold: {:?}",
        q.knee
    );
    let knee = c.knee.as_ref().expect("hogging 3 of 4 DCs must break the goodput floor");
    assert!(
        knee.reason.contains("goodput"),
        "starved jobs break the goodput floor, got: {}",
        knee.reason
    );
    assert!(
        c.completed < q.completed,
        "chaos cell completed {} >= quiet {}",
        c.completed,
        q.completed
    );
}
