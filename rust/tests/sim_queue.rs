//! Differential + property suites for the event-queue overhaul.
//!
//! The sim core swapped its `BinaryHeap<Box<FnOnce>>` + tombstone-set
//! queue for a generation-stamped slab feeding an index-only 4-ary heap.
//! The pre-swap engine is vendored as [`LegacyQueue`]; these suites prove
//! the swap preserved semantics *exactly*:
//!
//! * generated schedule/cancel/pop interleavings (via
//!   `testkit::forall_cases` with a shrinking script generator) replayed
//!   on both engines **and** a naive `Vec`-scan reference model, with
//!   bit-identical pop streams and exact `pending()` at every step;
//! * whole randomly-generated *simulations* (events scheduling children,
//!   deferring, cancelling each other) run on both engines with
//!   bit-identical replay digests;
//! * the `run_until`/`every` horizon-boundary contract (queue invariant
//!   5 in `rust/src/sim/mod.rs`).

use houtu::sim::{every, EventId, LegacyQueue, QueueKind, Sim, SimTime, SlabQueue};
use houtu::testkit::{forall_cases, Gen};
use houtu::trace::Fnv64;
use houtu::util::Pcg;
use houtu::prop_assert;

use std::cell::RefCell;
use std::rc::Rc;

// ---------------------------------------------------------------------------
// Queue-level differential: generated op scripts vs a Vec-scan model.
// ---------------------------------------------------------------------------

/// One step of a queue-driving script. `Cancel` indexes into the ids
/// issued so far (mod count), so scripts stay valid under shrinking.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Op {
    Schedule(u16),
    Cancel(u8),
    Pop,
    Peek,
}

/// Script generator with a drop-based shrink (every candidate is a
/// strictly shorter script, honouring the `Gen` contract).
struct OpsGen;

impl Gen<Vec<Op>> for OpsGen {
    fn generate(&self, rng: &mut Pcg) -> Vec<Op> {
        let len = 20 + rng.index(180);
        (0..len)
            .map(|_| match rng.index(10) {
                0..=4 => Op::Schedule(rng.below(1000) as u16),
                5 | 6 => Op::Cancel(rng.below(256) as u8),
                7 | 8 => Op::Pop,
                _ => Op::Peek,
            })
            .collect()
    }

    fn shrink(&self, v: &Vec<Op>) -> Vec<Vec<Op>> {
        let mut out = Vec::new();
        if v.len() > 1 {
            out.push(v[..v.len() / 2].to_vec());
            out.push(v[1..].to_vec());
            let mut minus_last = v.clone();
            minus_last.pop();
            out.push(minus_last);
        }
        out
    }
}

/// Naive reference model: a flat vec of live `(time, seq)` pairs, popped
/// by linear min-scan. Obviously correct, O(n) everything.
#[derive(Default)]
struct VecModel {
    live: Vec<(SimTime, u64)>,
}

impl VecModel {
    fn schedule(&mut self, time: SimTime, seq: u64) {
        self.live.push((time, seq));
    }

    fn cancel(&mut self, seq: u64) -> bool {
        match self.live.iter().position(|&(_, s)| s == seq) {
            Some(i) => {
                self.live.remove(i);
                true
            }
            None => false,
        }
    }

    fn min_index(&self) -> Option<usize> {
        (0..self.live.len()).min_by_key(|&i| self.live[i])
    }

    fn pop(&mut self) -> Option<(SimTime, u64)> {
        self.min_index().map(|i| self.live.remove(i))
    }

    fn next_time(&self) -> Option<SimTime> {
        self.min_index().map(|i| self.live[i].0)
    }

    fn pending(&self) -> usize {
        self.live.len()
    }
}

/// Pop all three implementations once and check they agree; fold the
/// popped `(time, seq)` into each engine's replay digest.
fn pop_pair(
    slab: &mut SlabQueue<()>,
    legacy: &mut LegacyQueue<()>,
    model: &mut VecModel,
    dig_slab: &mut Fnv64,
    dig_legacy: &mut Fnv64,
) -> Result<(), String> {
    let a = slab.pop().map(|p| (p.time, p.seq));
    let b = legacy.pop().map(|p| (p.time, p.seq));
    let m = model.pop();
    prop_assert!(a == b, "pop diverged: slab {a:?} vs legacy {b:?}");
    prop_assert!(a == m, "pop diverged from model: {a:?} vs {m:?}");
    if let Some((t, s)) = a {
        dig_slab.u64(t);
        dig_slab.u64(s);
    }
    if let Some((t, s)) = b {
        dig_legacy.u64(t);
        dig_legacy.u64(s);
    }
    Ok(())
}

/// Replay one script on all three implementations, checking agreement at
/// every step and folding each pop stream into a digest; ends with a
/// full drain plus a cancel-after-fire sweep over every id ever issued.
fn run_script(ops: &[Op]) -> Result<(), String> {
    let mut slab: SlabQueue<()> = SlabQueue::new();
    let mut legacy: LegacyQueue<()> = LegacyQueue::new();
    let mut model = VecModel::default();
    let mut seq = 0u64;
    // Parallel id books: the two engines issue different EventId
    // encodings for the same schedule, so cancels address by position.
    let mut ids: Vec<(EventId, EventId, u64)> = Vec::new();
    let mut dig_slab = Fnv64::new();
    let mut dig_legacy = Fnv64::new();
    for op in ops {
        match *op {
            Op::Schedule(t) => {
                let t = t as SimTime;
                let a = slab.schedule(t, seq, ());
                let b = legacy.schedule(t, seq, ());
                model.schedule(t, seq);
                ids.push((a, b, seq));
                seq += 1;
            }
            Op::Cancel(raw) => {
                if !ids.is_empty() {
                    let (a, b, s) = ids[raw as usize % ids.len()];
                    let ra = slab.cancel(a);
                    let rb = legacy.cancel(b);
                    let rm = model.cancel(s);
                    prop_assert!(
                        ra == rb && ra == rm,
                        "cancel diverged: slab {ra} legacy {rb} model {rm}"
                    );
                }
            }
            Op::Pop => {
                pop_pair(&mut slab, &mut legacy, &mut model, &mut dig_slab, &mut dig_legacy)?;
            }
            Op::Peek => {
                let a = slab.next_time();
                let b = legacy.next_time();
                let m = model.next_time();
                prop_assert!(a == b && a == m, "next_time diverged: {a:?} {b:?} {m:?}");
            }
        }
        prop_assert!(
            slab.pending() == model.pending() && legacy.pending() == model.pending(),
            "pending diverged: slab {} legacy {} model {}",
            slab.pending(),
            legacy.pending(),
            model.pending()
        );
    }
    // Drain to empty: the tails must agree too.
    while model.pending() > 0 {
        pop_pair(&mut slab, &mut legacy, &mut model, &mut dig_slab, &mut dig_legacy)?;
    }
    prop_assert!(slab.pop().is_none() && legacy.pop().is_none(), "ghost events after drain");
    // Every id is now fired or cancelled: cancel must be a universal
    // no-op reporting false on all implementations.
    for &(a, b, s) in &ids {
        let (ra, rb, rm) = (slab.cancel(a), legacy.cancel(b), model.cancel(s));
        prop_assert!(!ra && !rb && !rm, "cancel-after-fire not a no-op: {ra} {rb} {rm}");
    }
    prop_assert!(
        slab.pending() == 0 && legacy.pending() == 0,
        "stale cancels corrupted pending()"
    );
    prop_assert!(
        dig_slab.0 == dig_legacy.0,
        "replay digests diverged: {:016x} vs {:016x}",
        dig_slab.0,
        dig_legacy.0
    );
    Ok(())
}

#[test]
fn generated_schedules_replay_identically_on_old_and_new_queue() {
    forall_cases(0xD1FF, 192, &OpsGen, |ops: &Vec<Op>| run_script(ops));
}

#[test]
fn same_time_fifo_order_is_exact() {
    // All events at one timestamp: the pop stream must be schedule order
    // on both engines (the determinism contract replay digests pin).
    let mut slab: SlabQueue<()> = SlabQueue::new();
    let mut legacy: LegacyQueue<()> = LegacyQueue::new();
    for seq in 0..500u64 {
        slab.schedule(77, seq, ());
        legacy.schedule(77, seq, ());
    }
    for expect in 0..500u64 {
        assert_eq!(slab.pop().map(|p| p.seq), Some(expect), "slab broke FIFO at {expect}");
        assert_eq!(legacy.pop().map(|p| p.seq), Some(expect), "legacy broke FIFO at {expect}");
    }
}

// ---------------------------------------------------------------------------
// Sim-level differential: whole generated simulations, digest-compared.
// ---------------------------------------------------------------------------

/// Recorder world: folds everything observable about execution order —
/// (now, tag, pending-at-fire, cancel outcomes) — into one digest.
struct Rec {
    h: Fnv64,
    ids: Vec<EventId>,
}

fn run_generated_sim(kind: QueueKind, seed: u64) -> (u64, u64, usize) {
    let mut sim = Sim::with_queue(Rec { h: Fnv64::new(), ids: Vec::new() }, kind);
    let mut rng = Pcg::seeded(seed);
    for i in 0..400u64 {
        let t = rng.below(40_000);
        let spawn_child = rng.chance(0.3);
        let child_dt = rng.below(5_000);
        let defer_too = rng.chance(0.15);
        let cancel_idx = if rng.chance(0.25) { Some(rng.index(400)) } else { None };
        let id = sim.schedule_at(t, move |sim| {
            let now = sim.now();
            let pending = sim.pending() as u64;
            sim.state.h.u64(now);
            sim.state.h.u64(i);
            sim.state.h.u64(pending);
            if let Some(j) = cancel_idx {
                if j < sim.state.ids.len() {
                    let target = sim.state.ids[j];
                    let hit = sim.cancel(target);
                    sim.state.h.u64(hit as u64);
                }
            }
            if spawn_child {
                sim.schedule_in(child_dt, move |sim| {
                    let now = sim.now();
                    sim.state.h.u64(now ^ 0xC0DE);
                    sim.state.h.u64(i);
                });
            }
            if defer_too {
                sim.defer(move |sim| {
                    let now = sim.now();
                    sim.state.h.u64(now ^ 0xDEFE);
                    sim.state.h.u64(i);
                });
            }
        });
        sim.state.ids.push(id);
    }
    // Split the run across a horizon boundary to exercise run_until's
    // lazy-skip path, then drain.
    sim.run_until(20_000);
    sim.run_to_completion();
    (sim.state.h.0, sim.events_processed, sim.peak_pending())
}

#[test]
fn generated_sims_digest_identically_on_old_and_new_queue() {
    for seed in [1u64, 42, 7, 1234, 0xFEED] {
        let slab = run_generated_sim(QueueKind::Slab, seed);
        let legacy = run_generated_sim(QueueKind::Legacy, seed);
        assert_eq!(slab, legacy, "seed {seed}: execution diverged between engines");
        let again = run_generated_sim(QueueKind::Slab, seed);
        assert_eq!(slab, again, "seed {seed}: slab engine is not deterministic");
    }
}

// ---------------------------------------------------------------------------
// Horizon-boundary regression pins (Sim::run_until / every).
// ---------------------------------------------------------------------------

#[test]
fn periodic_tick_landing_exactly_on_horizon_fires_on_both_engines() {
    for kind in [QueueKind::Slab, QueueKind::Legacy] {
        let ticks: Rc<RefCell<Vec<SimTime>>> = Rc::default();
        let t2 = ticks.clone();
        let mut sim = Sim::with_queue((), kind);
        every(&mut sim, 1_000, move |sim| {
            t2.borrow_mut().push(sim.now());
            true
        });
        sim.run_until(5_000);
        assert_eq!(
            *ticks.borrow(),
            vec![0, 1_000, 2_000, 3_000, 4_000, 5_000],
            "{kind:?}: the tick scheduled exactly at the horizon must fire before the stop"
        );
        assert_eq!(sim.now(), 5_000, "{kind:?}: clock parks on the horizon");
        // The re-arm for 6000 is queued, not lost and not fired early.
        assert_eq!(sim.pending(), 1, "{kind:?}");
        sim.run_until(5_999);
        assert_eq!(ticks.borrow().len(), 6, "{kind:?}: nothing extra before the next period");
        sim.run_until(6_000);
        assert_eq!(ticks.borrow().last(), Some(&6_000), "{kind:?}");
    }
}

#[test]
fn horizon_events_scheduled_at_the_horizon_by_horizon_events_fire() {
    // An event at t spawns same-time work (defer and schedule_at(t));
    // run_until(t) must drain the whole chain, exactly like the campaign
    // runner's final scheduling period at its horizon.
    let mut sim = Sim::new(Vec::<u32>::new());
    sim.schedule_at(9_000, |sim| {
        sim.state.push(1);
        let t = sim.now();
        sim.schedule_at(t, |sim| sim.state.push(2));
        sim.defer(|sim| sim.state.push(3));
    });
    sim.run_until(9_000);
    assert_eq!(sim.state, vec![1, 2, 3]);
    assert_eq!(sim.pending(), 0);
    assert_eq!(sim.now(), 9_000);
}
