//! Property tests over the scheduler core, via `testkit::forall`:
//! Parades assignment invariants (no over-commit, threshold gating),
//! the work-stealing gate (a JM steals only with an empty queue), and the
//! master's fair scheduler (a ≤ d, max-min ordering, FIFO vs FairShare
//! conservation).

use houtu::cloud::InstanceClass;
use houtu::cluster::Cluster;
use houtu::deploy::should_steal;
use houtu::ids::{ContainerId, DcId, JmId, JobId, NodeId, StageId, TaskId};
use houtu::jm::{on_update, ContainerView, JobManager, Locality, ParadesParams, Role, WaitingTask};
use houtu::master::{AllocPolicy, Master};
use houtu::prop_assert;
use houtu::testkit::{forall, forall_cases, Gen, UsizeIn, VecOf};
use houtu::util::Pcg;

const PARAMS: ParadesParams = ParadesParams { delta: 0.7, tau: 0.5 };

fn random_task(rng: &mut Pcg, i: u32) -> WaitingTask {
    let pref = if rng.chance(0.7) {
        Some(NodeId { dc: DcId(rng.index(3)), idx: rng.index(4) })
    } else {
        None
    };
    WaitingTask {
        id: TaskId { job: JobId(1), stage: StageId(0), index: i },
        r: rng.uniform(0.05, 0.95),
        p: rng.uniform(0.5, 30.0),
        input_bytes: 1,
        pref_node: pref,
        pref_rack: pref.map(|nd| (nd.dc, nd.idx % 2)),
        wait: rng.uniform(0.0, 40.0),
    }
}

#[derive(Clone, Debug)]
struct QueueCase {
    tasks: Vec<WaitingTask>,
    free: f64,
    node: NodeId,
    steal: bool,
}

struct QueueGen;

impl Gen<QueueCase> for QueueGen {
    fn generate(&self, rng: &mut Pcg) -> QueueCase {
        let n = rng.index(10);
        QueueCase {
            tasks: (0..n).map(|i| random_task(rng, i as u32)).collect(),
            free: rng.uniform(0.0, 1.0),
            node: NodeId { dc: DcId(rng.index(3)), idx: rng.index(4) },
            steal: rng.chance(0.3),
        }
    }
}

fn view_of(case: &QueueCase) -> ContainerView {
    ContainerView { id: ContainerId(1), node: case.node, rack: case.node.idx % 2, free: case.free }
}

/// Parades never commits more than the container's free capacity, and
/// every single assignment fits the capacity remaining at its turn.
#[test]
fn prop_parades_never_overcommits() {
    forall(0x5EED1, &QueueGen, |case: &QueueCase| {
        let mut q = case.tasks.clone();
        let picks = on_update(&mut q, view_of(case), PARAMS, case.steal);
        let mut free = case.free;
        for a in &picks {
            prop_assert!(a.task.r <= free + 1e-6, "r {} > remaining {free}", a.task.r);
            free -= a.task.r;
        }
        prop_assert!(q.len() + picks.len() == case.tasks.len(), "task conservation");
        Ok(())
    });
}

/// Locality relaxation is gated: rack-local only after `τ·p`, any/stolen
/// placement only after `2τ·p` on a nearly-free container.
#[test]
fn prop_parades_locality_gates() {
    forall(0x5EED2, &QueueGen, |case: &QueueCase| {
        let mut q = case.tasks.clone();
        let picks = on_update(&mut q, view_of(case), PARAMS, case.steal);
        for (k, a) in picks.iter().enumerate() {
            match a.locality {
                Locality::NodeLocal => {
                    prop_assert!(a.task.pref_node == Some(case.node), "node-local mismatch");
                    prop_assert!(!case.steal, "steal produced a node-local assignment");
                }
                Locality::RackLocal => prop_assert!(
                    a.task.wait + 1e-9 >= PARAMS.tau * a.task.p,
                    "rack gate: wait {} < {}",
                    a.task.wait,
                    PARAMS.tau * a.task.p
                ),
                Locality::Any | Locality::Stolen => {
                    prop_assert!(
                        a.task.wait + 1e-9 >= 2.0 * PARAMS.tau * a.task.p,
                        "any gate: wait {} < {}",
                        a.task.wait,
                        2.0 * PARAMS.tau * a.task.p
                    );
                    let free_then: f64 =
                        case.free - picks[..k].iter().map(|x| x.task.r).sum::<f64>();
                    // The *first* any-clause pick needs a nearly-free
                    // container w.r.t. capacity at its turn.
                    if !picks[..k]
                        .iter()
                        .any(|x| matches!(x.locality, Locality::Any | Locality::Stolen))
                    {
                        prop_assert!(
                            free_then + 1e-6 >= 1.0 - PARAMS.delta,
                            "any clause on busy container: free {free_then}"
                        );
                    }
                }
            }
            prop_assert!(
                (a.locality == Locality::Stolen) == case.steal,
                "steal labeling mismatch"
            );
        }
        Ok(())
    });
}

/// The steal gate: a thief must have an empty queue, no request already
/// in flight, and a nearly-idle container to offer.
#[test]
fn prop_steal_gate_requires_empty_queue() {
    struct GateGen;
    impl Gen<(bool, bool, f64, f64)> for GateGen {
        fn generate(&self, rng: &mut Pcg) -> (bool, bool, f64, f64) {
            (rng.chance(0.5), rng.chance(0.5), rng.uniform(-1.0, 1.0), rng.uniform(0.05, 0.95))
        }
    }
    forall(0x5EED3, &GateGen, |&(waiting, inflight, free, delta): &(bool, bool, f64, f64)| {
        if should_steal(waiting, inflight, free, delta) {
            prop_assert!(!waiting, "stole with waiting tasks of its own");
            prop_assert!(!inflight, "stole with a request already in flight");
            prop_assert!(free + 1e-6 >= 1.0 - delta, "offered container not idle enough");
        } else {
            prop_assert!(
                waiting || inflight || free + 1e-9 < 1.0 - delta,
                "gate refused a legal steal"
            );
        }
        Ok(())
    });
}

/// Victim side of a steal: only tasks past the `2τ·p` patience leak out,
/// and the stolen-out counter tracks exactly what left the queue.
#[test]
fn prop_steal_request_takes_only_patient_tasks() {
    forall(0x5EED4, &QueueGen, |case: &QueueCase| {
        let mut victim = JobManager::new(
            JmId { job: JobId(1), dc: DcId(0) },
            Role::SemiActive,
            ContainerId(900),
            0.0,
        );
        victim.enqueue(case.tasks.clone());
        let before = victim.queue.len();
        // now_secs == last_update (0.0): no extra aging, pure gating.
        let picks = victim.handle_steal_request(view_of(case), 0.0, PARAMS);
        prop_assert!(
            victim.stats.tasks_stolen_out == picks.len() as u64,
            "stolen-out counter mismatch"
        );
        prop_assert!(victim.queue.len() + picks.len() == before, "steal lost tasks");
        for a in &picks {
            prop_assert!(a.locality == Locality::Stolen, "steal path mislabeled");
            prop_assert!(
                a.task.wait + 1e-9 >= 2.0 * PARAMS.tau * a.task.p,
                "impatient task stolen"
            );
        }
        Ok(())
    });
}

fn cluster_with(n: usize) -> Cluster {
    Cluster::build(&["A".into()], n, 1, 2, |_, _| InstanceClass::OnDemand)
}

fn jm(j: usize) -> JmId {
    JmId { job: JobId(j as u64), dc: DcId(0) }
}

fn allocate_with(policy: AllocPolicy, desires: &[usize], capacity: usize) -> Vec<usize> {
    let mut cluster = cluster_with(capacity);
    let mut m = Master::new(DcId(0));
    m.policy = policy;
    for (j, &d) in desires.iter().enumerate() {
        m.register(jm(j));
        m.set_desire(jm(j), d);
    }
    m.allocate(&mut cluster);
    (0..desires.len()).map(|j| m.allocation(jm(j))).collect()
}

/// Both policies: allocation never exceeds desire, and grants never
/// exceed the pool.
#[test]
fn prop_allocation_never_exceeds_desire_under_either_policy() {
    let gen = VecOf { elem: UsizeIn(0, 15), min_len: 1, max_len: 8 };
    forall(0xFA2, &gen, |desires: &Vec<usize>| {
        for policy in [AllocPolicy::FairShare, AllocPolicy::Fifo] {
            let allocs = allocate_with(policy, desires, 10);
            for (j, (&a, &d)) in allocs.iter().zip(desires).enumerate() {
                prop_assert!(a <= d, "{policy:?} job {j}: a={a} > d={d}");
            }
            let total: usize = allocs.iter().sum();
            prop_assert!(total <= 10, "{policy:?}: granted {total} from a pool of 10");
            let want: usize = desires.iter().sum();
            prop_assert!(total == want.min(10), "{policy:?}: {total} != min({want}, 10)");
        }
        Ok(())
    });
}

/// Max-min share ordering: under FairShare, a sub-job never ends more
/// than one container ahead of a hungrier (higher-desire) sub-job.
#[test]
fn prop_fair_share_is_max_min_ordered() {
    let gen = VecOf { elem: UsizeIn(0, 15), min_len: 2, max_len: 8 };
    forall(0xFA3, &gen, |desires: &Vec<usize>| {
        let allocs = allocate_with(AllocPolicy::FairShare, desires, 10);
        for i in 0..desires.len() {
            for j in 0..desires.len() {
                if desires[i] <= desires[j] {
                    prop_assert!(
                        allocs[i] <= allocs[j] + 1,
                        "d{i}={} ≤ d{j}={} but a{i}={} > a{j}={}+1",
                        desires[i],
                        desires[j],
                        allocs[i],
                        allocs[j]
                    );
                }
            }
        }
        Ok(())
    });
}

/// FIFO and FairShare hand out the same *total* (conservation) — they
/// differ only in ordering; and FIFO's order is strictly by job id:
/// a prefix of jobs is fully satisfied, at most one is partial, the rest
/// get nothing.
#[test]
fn prop_fifo_vs_fair_share_conserve_grants() {
    let gen = VecOf { elem: UsizeIn(0, 15), min_len: 1, max_len: 8 };
    forall_cases(0xFA4, 256, &gen, |desires: &Vec<usize>| {
        let fair = allocate_with(AllocPolicy::FairShare, desires, 10);
        let fifo = allocate_with(AllocPolicy::Fifo, desires, 10);
        prop_assert!(
            fair.iter().sum::<usize>() == fifo.iter().sum::<usize>(),
            "totals differ: fair {fair:?} vs fifo {fifo:?}"
        );
        let mut exhausted = false;
        for (j, (&a, &d)) in fifo.iter().zip(desires).enumerate() {
            if exhausted {
                prop_assert!(a == 0, "fifo job {j} got {a} after the pool ran dry");
            } else if a < d {
                exhausted = true; // the one partial job; everything after gets 0
            }
        }
        Ok(())
    });
}
