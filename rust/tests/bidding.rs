//! Cost-aware bidding + insurance replication: end-to-end acceptance
//! tests. The headline pin: on the standard spot-storm scenario the
//! `AdaptivePredictor` strategy ends the run strictly cheaper (total
//! USD) than the `Naive` baseline, summed over the campaign's pinned
//! seeds — and the naive baseline itself remains bit-identical to the
//! pre-subsystem event stream.

use houtu::cloud::InstanceClass;
use houtu::config::{Config, Deployment};
use houtu::deploy::World;
use houtu::scenario::{run_one, run_scenario, standard_campaign, ScenarioSpec};

fn spot_storm_spec() -> ScenarioSpec {
    standard_campaign()
        .scenarios
        .iter()
        .find(|s| s.name == "spot-storm")
        .expect("standard campaign ships a spot-storm scenario")
        .clone()
}

fn with_strategy(base: &ScenarioSpec, strategy: &str) -> ScenarioSpec {
    let mut spec = base.clone();
    spec.name = format!("{}-{strategy}", spec.name);
    spec.overrides.push(format!("bidding.strategy={strategy}"));
    spec
}

/// The tentpole acceptance pin: the EWMA forecaster's volatility-scaled
/// bids must translate into fewer revocations and a strictly cheaper run
/// than the blind baseline, summed over the standard campaign's pinned
/// seeds. The spot-storm scenario is hardened (faster market, lower
/// naive bid, higher base volatility) so the baseline reliably suffers
/// revocation churn at every seed while the adaptive floor — `forecast ×
/// (1 + 4·vol)` — stays well clear of the spikes.
#[test]
fn adaptive_strategy_is_cheaper_than_naive_on_the_spot_storm() {
    let base = Config::default();
    let mut storm = spot_storm_spec();
    storm.overrides.extend([
        "cloud.spot_volatility=0.35".to_string(),
        "cloud.market_period_secs=60.0".to_string(),
        "cloud.bid_multiplier=1.3".to_string(),
    ]);
    let naive = with_strategy(&storm, "naive");
    let adaptive = with_strategy(&storm, "adaptive");
    let mut naive_usd = 0.0;
    let mut adaptive_usd = 0.0;
    for seed in [42u64, 7, 1234] {
        let n = run_one(&base, &naive, seed);
        let a = run_one(&base, &adaptive, seed);
        assert!(n.passed(), "naive/seed{seed}: {:?}", n.violations);
        assert!(a.passed(), "adaptive/seed{seed}: {:?}", a.violations);
        assert_eq!(n.completed_jobs, n.total_jobs, "naive/seed{seed}");
        assert_eq!(a.completed_jobs, a.total_jobs, "adaptive/seed{seed}");
        assert!(n.total_usd > 0.0 && a.total_usd > 0.0);
        naive_usd += n.total_usd;
        adaptive_usd += a.total_usd;
    }
    assert!(
        adaptive_usd < naive_usd,
        "adaptive must end the storm cheaper: adaptive ${adaptive_usd:.3} vs naive ${naive_usd:.3}"
    );
}

/// The naive strategy (the default) is not a near-copy of the old code —
/// it IS the old code path: explicitly configuring it must replay to the
/// same digest as the untouched default, while a non-naive strategy (new
/// RNG-independent decisions + `BidPlaced`/`CostCharged` events) must
/// visibly change the stream.
#[test]
fn naive_baseline_replays_bit_identically_and_adaptive_diverges() {
    let base = Config::default();
    let storm = spot_storm_spec();
    let explicit_naive = with_strategy(&storm, "naive");
    let adaptive = with_strategy(&storm, "adaptive");
    let default_run = run_one(&base, &storm, 42);
    let naive_run = run_one(&base, &explicit_naive, 42);
    let adaptive_run = run_one(&base, &adaptive, 42);
    assert!(default_run.passed(), "{:?}", default_run.violations);
    assert_eq!(
        default_run.digest, naive_run.digest,
        "bidding.strategy=naive must be a byte-identical no-op"
    );
    assert_eq!(default_run.events_processed, naive_run.events_processed);
    assert_ne!(
        default_run.digest, adaptive_run.digest,
        "the adaptive strategy must leave a trace in the stream"
    );
}

/// The shipped bid-insurance-storm cell: insurance duplicates launch
/// under revocation pressure and the duplicate-safe exactly-once stack
/// stays clean, deterministically.
#[test]
fn insurance_replication_is_duplicate_safe_and_deterministic() {
    let base = Config::default();
    let campaign = standard_campaign();
    let spec = campaign
        .scenarios
        .iter()
        .find(|s| s.name == "bid-insurance-storm")
        .expect("standard campaign ships the bid-insurance scenario")
        .clone();
    for seed in [42u64, 7] {
        let a = run_one(&base, &spec, seed);
        let b = run_one(&base, &spec, seed);
        assert!(a.passed(), "seed{seed}: {:?}", a.violations);
        assert_eq!(a.completed_jobs, a.total_jobs, "seed{seed}");
        assert_eq!(a.digest, b.digest, "seed{seed}: insurance broke replay determinism");
        assert_eq!(a.events_processed, b.events_processed, "seed{seed}");
    }
}

/// Per-job cost attribution: every completed job carries a positive
/// CostMeter total, the report's `job_usd` column sums them, and a
/// finished job's remaining critical path collapses to zero (the
/// deadline strategy's progress signal).
#[test]
fn per_job_cost_and_critical_path_fold_through_the_run() {
    let base = Config::default();
    let storm = with_strategy(&spot_storm_spec(), "adaptive");
    let run = run_scenario(&base, &storm, 42).unwrap();
    let w = &run.world;
    assert!(w.metrics.completed_jobs() > 0);
    let mut sum = 0.0;
    for (id, rt) in &w.jobs {
        let usd = rt.cost.total_usd();
        assert!(usd > 0.0, "{id}: job finished with zero attributed cost");
        assert!(usd.is_finite());
        sum += usd;
        assert_eq!(
            rt.remaining_critical_path(),
            0.0,
            "{id}: finished job still reports remaining critical path"
        );
    }
    let rep = run_one(&base, &storm, 42);
    assert!((rep.job_usd - sum).abs() < 1e-9, "job_usd column must sum the per-job meters");
    assert!(
        rep.job_usd < rep.total_usd,
        "attributed task occupancy must undercut whole-testbed billing"
    );
}

/// Mid-run spot→on-demand conversions must be billed per segment, not
/// at the final class for the whole makespan: a node converted halfway
/// through a one-hour run costs half an hour at each rate. Without any
/// recorded flip the billing stays bit-identical to the single-segment
/// baseline.
#[test]
fn mid_run_class_conversion_bills_segmented_hours() {
    let cfg = Config::default();
    let mk = || World::new(cfg.clone(), Deployment::Houtu);
    // Pick a spot worker node (all workers are spot on houtu).
    let node = houtu::ids::NodeId { dc: houtu::ids::DcId(1), idx: 2 };
    let mut base = mk();
    assert!(base.cluster.node_class(node).is_spot(), "expected a spot worker");
    base.bill_machines(3600.0);
    let mut converted = mk();
    let old = converted.cluster.node_class(node);
    converted.class_changes.push((node, 1800.0, old));
    converted.cluster.set_node_class(node, InstanceClass::OnDemand);
    converted.bill_machines(3600.0);
    let expected_delta = 0.5 * (cfg.cloud.on_demand_hourly - cfg.cloud.spot_hourly_mean);
    let delta = converted.cost.machine_usd - base.cost.machine_usd;
    assert!(
        (delta - expected_delta).abs() < 1e-9,
        "segmented billing delta {delta} != half-hour premium {expected_delta}"
    );
    // No flips recorded ⇒ bit-identical to the pre-subsystem billing.
    let mut twin = mk();
    twin.bill_machines(3600.0);
    assert_eq!(twin.cost.machine_usd.to_bits(), base.cost.machine_usd.to_bits());
}

/// The deadline strategy end-to-end: a tight soft deadline plus budget
/// runs clean (the strategy only changes bid levels and container-class
/// preferences, never correctness), and urgency reads zero once done.
#[test]
fn deadline_strategy_runs_clean_under_tight_deadlines() {
    let base = Config::default();
    let mut spec = with_strategy(&spot_storm_spec(), "deadline");
    spec.overrides.push("workload.deadline_secs=120".to_string());
    spec.overrides.push("workload.budget_usd=0.5".to_string());
    let rep = run_one(&base, &spec, 42);
    assert!(rep.passed(), "{:?}", rep.violations);
    assert_eq!(rep.completed_jobs, rep.total_jobs);
    let run = run_scenario(&base, &spec, 42).unwrap();
    assert_eq!(run.world.job_urgency(1e9), 0.0, "no active jobs ⇒ no urgency");
}
