//! Trace-bus integration tests.
//!
//! * **Metrics-via-trace parity** — `Metrics` is a pure fold over the
//!   event stream: folding a ring-buffer capture into a fresh `Metrics`
//!   must reproduce the live one exactly, which pins the figure outputs
//!   (Fig 8/9/11/12 all read `Metrics`) to the pre-refactor behaviour.
//! * **Digest determinism** — the trace-folded replay digest is
//!   identical across campaign worker counts (1 vs N threads) and across
//!   repeated runs of random (scenario, seed) cells.

use houtu::config::{Config, Deployment};
use houtu::dag::{SizeClass, WorkloadKind};
use houtu::deploy::{build_sim, submit_job, World};
use houtu::ids::{DcId, JobId};
use houtu::metrics::Metrics;
use houtu::scenario::{
    presets, run_campaign, run_one, run_scenario, smoke_campaign, ScenarioSpec, ScenarioWorkload,
};
use houtu::sim::secs;
use houtu::trace::{CountingSink, RingBuffer, RingSink, TraceSink};
use houtu::util::Pcg;

/// Run one job with a full-stream ring capture attached; return the
/// finished world and the capture.
fn captured_single_job(
    kind: WorkloadKind,
    size: SizeClass,
    home: DcId,
) -> (World, std::rc::Rc<std::cell::RefCell<RingBuffer>>) {
    let cfg = Config::default();
    let horizon = secs(14_400);
    let mut sim = build_sim(cfg, Deployment::Houtu, horizon);
    let ring = RingBuffer::shared(4_000_000);
    sim.state.tracer.attach(Box::new(RingSink(ring.clone())));
    sim.schedule_at(1, move |sim| {
        submit_job(sim, kind, size, home);
    });
    sim.run_until(horizon);
    (sim.state, ring)
}

#[test]
fn metrics_are_exactly_the_trace_fold() {
    let (world, ring) = captured_single_job(WorkloadKind::WordCount, SizeClass::Medium, DcId(0));
    assert_eq!(world.metrics.completed_jobs(), 1);
    let ring = ring.borrow();
    assert_eq!(ring.pushed as usize, ring.len(), "capture must not have wrapped");
    let mut folded = Metrics::default();
    for ev in ring.iter() {
        folded.on_event(ev);
    }
    assert_eq!(folded, world.metrics, "Metrics must be a pure fold of the event stream");
}

/// The figure-level quantities a clean run must reproduce (no failures
/// injected, default config has revocations and stragglers off): the
/// Fig-9 launch timeline is cumulative 1..=N with N = the job's task
/// count, and the Fig-11 container timeline rises from the JM spawn and
/// returns to zero at completion. These pin the trace-fed `Metrics` to
/// the semantics the direct pushes had.
#[test]
fn clean_run_figure_outputs_hold() {
    let (world, _) = captured_single_job(WorkloadKind::PageRank, SizeClass::Small, DcId(1));
    let rec = &world.metrics.jobs[&JobId(0)];
    assert!(rec.jrt().unwrap() > 0.0);

    let launches = &world.metrics.task_launches[&JobId(0)];
    assert_eq!(launches.len(), rec.tasks_total, "every task launched exactly once");
    for (i, &(t, c)) in launches.iter().enumerate() {
        assert_eq!(c, (i + 1) as f64, "cumulative count");
        assert!(t >= rec.submitted_secs);
    }

    let containers = &world.metrics.containers[&JobId(0)];
    assert!(containers.first().unwrap().1 > 0.0, "JM spawn registers containers");
    assert_eq!(containers.last().unwrap().1, 0.0, "all containers released at the end");

    let infos = &world.metrics.info_sizes[&rec.kind];
    assert!(!infos.is_empty(), "replication sampled info sizes");
}

#[test]
fn trace_counts_match_world_ground_truth() {
    let cfg = Config::default();
    let spec = presets::fig11_kill(DcId(0), Deployment::Houtu);
    let horizon = secs(14_400);
    // Rebuild the preset by hand so we can attach a counting sink before
    // the run starts.
    let run_cfg = spec.build_config(&cfg, cfg.seed).unwrap();
    let mut sim = build_sim(run_cfg, Deployment::Houtu, horizon);
    let (sink, counts) = CountingSink::shared();
    sim.state.tracer.attach(Box::new(sink));
    sim.schedule_at(1, |sim| {
        submit_job(sim, WorkloadKind::WordCount, SizeClass::Large, DcId(0));
    });
    sim.schedule_at(secs(70), |sim| {
        houtu::deploy::kill_jm_host(sim, JobId(0), DcId(0));
    });
    sim.run_until(horizon);
    let w = &sim.state;
    assert_eq!(w.metrics.completed_jobs(), 1);
    let counts = counts.borrow();
    let get = |k: &str| counts.get(k).copied().unwrap_or(0);
    assert_eq!(get("job-submitted"), 1);
    assert_eq!(get("job-completed"), 1);
    assert_eq!(get("task-finished") as usize, w.metrics.jobs[&JobId(0)].tasks_total);
    assert!(get("task-launched") >= get("task-finished"));
    assert_eq!(get("election-won") as usize, w.metrics.election_delays_secs.len());
    assert_eq!(get("jm-recovered") as usize, w.metrics.recovery_intervals_secs.len());
    assert_eq!(get("steal-completed") as usize, w.metrics.steal_delays_ms.len());
    assert!(get("node-killed") >= 1, "the kill must be on the record");
    assert!(get("wan-transfer") >= 1);
    assert!(get("info-replicated") >= 1);
}

#[test]
fn campaign_digest_is_worker_count_invariant() {
    let base = Config::default();
    let mut spec = smoke_campaign();
    spec.parallelism = 1;
    let serial = run_campaign(&base, &spec);
    spec.parallelism = 4;
    let parallel = run_campaign(&base, &spec);
    assert!(serial.all_pass(), "{}", serial.render());
    assert!(parallel.all_pass(), "{}", parallel.render());
    assert_eq!(
        serial.campaign_digest, parallel.campaign_digest,
        "digest must not depend on worker count"
    );
    for (a, b) in serial.runs.iter().zip(&parallel.runs) {
        assert_eq!(a.digest, b.digest, "{}/seed{}", a.scenario, a.seed);
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.avg_jrt_secs.to_bits(), b.avg_jrt_secs.to_bits());
    }
}

/// Property: random (scenario, seed) cells replay to identical digests.
#[test]
fn random_cells_replay_identically() {
    let base = Config::default();
    let mut rng = Pcg::seeded(0xC0FFEE);
    let kinds = WorkloadKind::ALL;
    for i in 0..3u32 {
        let kind = kinds[rng.below(kinds.len() as u64) as usize];
        let home = DcId(rng.below(4) as usize);
        let seed = rng.below(10_000);
        let spec = ScenarioSpec {
            name: format!("rand-{i}"),
            deployment: Deployment::Houtu,
            regions: 0,
            workload: ScenarioWorkload::SingleJob { kind, size: SizeClass::Small, home },
            events: vec![],
            overrides: vec![],
        };
        let a = run_one(&base, &spec, seed);
        let b = run_one(&base, &spec, seed);
        assert!(a.passed(), "{kind:?}@{home}/seed{seed}: {:?}", a.violations);
        assert_eq!(a.digest, b.digest, "{kind:?}@{home}/seed{seed} must replay identically");
        assert_eq!(a.events_processed, b.events_processed);
    }
}

/// The digest now sees *order*: it differs across seeds even when the
/// end states are structurally similar (same scenario, same jobs).
#[test]
fn digest_differs_across_seeds() {
    let base = Config::default();
    let spec = ScenarioSpec {
        name: "order".into(),
        deployment: Deployment::Houtu,
        regions: 0,
        workload: ScenarioWorkload::SingleJob {
            kind: WorkloadKind::WordCount,
            size: SizeClass::Small,
            home: DcId(0),
        },
        events: vec![],
        overrides: vec![],
    };
    let a = run_one(&base, &spec, 1);
    let b = run_one(&base, &spec, 2);
    assert!(a.passed() && b.passed());
    assert_ne!(a.digest, b.digest);
}

/// The new chaos families run clean end to end through the engine.
#[test]
fn new_chaos_families_run_clean() {
    let base = Config::default();
    let std_campaign = houtu::scenario::standard_campaign();
    for name in ["asym-wan-partition", "jm-kill-cascade"] {
        let spec = std_campaign.scenarios.iter().find(|s| s.name == name).unwrap();
        let run = run_scenario(&base, spec, 42).unwrap();
        let violations = houtu::scenario::check_world(&run.world);
        assert!(violations.is_empty(), "{name}: {violations:?}");
        assert_eq!(run.world.metrics.completed_jobs(), 1, "{name}");
        if name == "jm-kill-cascade" {
            assert!(
                !run.world.metrics.election_delays_secs.is_empty(),
                "cascade must force at least one election"
            );
        }
        if name == "asym-wan-partition" {
            assert!(
                (run.world.wan.pair_degrade_factor(DcId(0), DcId(2)) - 1.0).abs() < 1e-12,
                "pair degradation must be restored"
            );
        }
    }
}
