//! Record → replay round-trips: a campaign recorded with the engine's
//! event recorder must re-execute bit-identically — same per-event log
//! lines, same full-stream FNV, same event counts, same run digests.
//! This is the persistent-determinism companion to `golden_digests` (which
//! pins digests across queue engines in-process): the event log survives
//! the process, so a replay failure in a later build means the binary no
//! longer executes the schedule it used to.

use houtu::config::Config;
use houtu::scenario::replay::{read_log, render_log};
use houtu::scenario::{
    record_campaign, record_cells, replay_log, smoke_campaign, standard_campaign,
};
use houtu::util::json;

#[test]
fn smoke_campaign_records_and_replays_bit_identically() {
    let base = Config::default();
    let log = record_campaign(&base, &smoke_campaign(), "smoke").expect("record");
    assert_eq!(log.cells.len(), 4, "2 scenarios x 2 seeds");
    for cell in &log.cells {
        assert!(cell.events > 0, "{}: empty run", cell.scenario);
        assert!(!cell.log.is_empty(), "{}: no lines kept", cell.scenario);
        assert_eq!(cell.queue, "slab");
    }
    let summary = replay_log(&base, &log).expect("replay must reproduce the recording");
    assert_eq!(summary.cells, 4);
    assert_eq!(summary.events, log.cells.iter().map(|c| c.events).sum::<u64>());
}

#[test]
fn smoke_log_survives_serialization() {
    let base = Config::default();
    let log = record_campaign(&base, &smoke_campaign(), "smoke").expect("record");
    let text = render_log(&log);
    let back = read_log(&text).expect("rendered log must parse");
    assert_eq!(back, log, "serialization round-trip");
    // Replay from the parsed copy, exactly what `houtu replay` does.
    replay_log(&base, &back).expect("replay from disk form");
}

#[test]
fn recorded_lines_are_valid_stamped_json() {
    let base = Config::default();
    let plans: Vec<_> = smoke_campaign()
        .expand()
        .into_iter()
        .filter(|(_, seed)| *seed == 42)
        .collect();
    let log = record_cells(&base, &plans, "smoke").expect("record");
    let cell = &log.cells[0];
    let mut last = (0u64, 0u64);
    for (i, line) in cell.log.iter().enumerate() {
        let v = json::parse(line).unwrap_or_else(|e| panic!("line {i} {line:?}: {e}"));
        let t = v.get("t").and_then(json::Json::as_u64).expect("t stamp");
        let seq = v.get("seq").and_then(json::Json::as_u64).expect("seq stamp");
        assert!(v.get("ev").and_then(json::Json::as_str).is_some(), "ev tag");
        if i > 0 {
            assert!(
                t > last.0 || (t == last.0 && seq > last.1),
                "line {i}: (t,seq) not monotone: {last:?} -> ({t},{seq})"
            );
        }
        last = (t, seq);
    }
}

#[test]
fn standard_campaign_cells_record_and_replay() {
    // A diverse slice of the standard campaign at one seed: baseline,
    // pJM kill + election, cascading kills, spot storm with revocations,
    // and the asymmetric WAN partition. (The full 30-cell matrix is
    // covered in-process by golden_digests; recording it here would run
    // it twice more, serially.)
    let keep = [
        "baseline-wordcount",
        "pjm-kill",
        "jm-kill-cascade",
        "spot-storm",
        "asym-wan-partition",
    ];
    let base = Config::default();
    let plans: Vec<_> = standard_campaign()
        .expand()
        .into_iter()
        .filter(|(sc, seed)| *seed == 42 && keep.contains(&sc.name.as_str()))
        .collect();
    assert_eq!(plans.len(), keep.len(), "every picked scenario exists");
    let log = record_cells(&base, &plans, "standard").expect("record");
    let summary = replay_log(&base, &log).expect("replay must reproduce the recording");
    assert_eq!(summary.cells, keep.len());
}

#[test]
fn tampered_logs_fail_replay() {
    let base = Config::default();
    let plans: Vec<_> = smoke_campaign()
        .expand()
        .into_iter()
        .filter(|(sc, seed)| *seed == 42 && sc.name == "baseline-wordcount")
        .collect();
    let log = record_cells(&base, &plans, "smoke").expect("record");

    // Flipped digest: the run itself matches, the final digest doesn't.
    let mut bad = log.clone();
    bad.cells[0].digest ^= 1;
    let err = replay_log(&base, &bad).expect_err("digest tamper must fail");
    assert!(format!("{err:#}").contains("digest"), "{err:#}");

    // Flipped stream hash.
    let mut bad = log.clone();
    bad.cells[0].log_fnv ^= 1;
    let err = replay_log(&base, &bad).expect_err("fnv tamper must fail");
    assert!(format!("{err:#}").contains("fnv"), "{err:#}");

    // Edited log line: lockstep comparison reports the exact line.
    let mut bad = log.clone();
    bad.cells[0].log[0] = "{\"t\":0,\"seq\":0,\"ev\":\"imposter\"}".to_string();
    let err = replay_log(&base, &bad).expect_err("line tamper must fail");
    assert!(format!("{err:#}").contains("diverged"), "{err:#}");

    // Wrong event count.
    let mut bad = log;
    bad.cells[0].events += 1;
    assert!(replay_log(&base, &bad).is_err(), "count tamper must fail");
}
