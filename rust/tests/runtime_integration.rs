//! Integration tests: the PJRT runtime executes the AOT artifacts with
//! correct numerics (requires `make artifacts` and a build with
//! `--features pjrt`; the default offline build ships a stub runtime).
#![cfg(feature = "pjrt")]

use houtu::runtime::{default_artifact_dir, Runtime, LOGREG_D, LOGREG_N, PAGERANK_N, SEG_K, SEG_N, SEG_V};
use houtu::util::Pcg;

fn runtime() -> Runtime {
    Runtime::load(&default_artifact_dir()).expect("artifacts missing — run `make artifacts`")
}

#[test]
fn logreg_training_reduces_loss_through_pjrt() {
    let rt = runtime();
    let mut rng = Pcg::seeded(7);
    // Separable synthetic data: y = 1 iff x . w_true > 0.
    let w_true: Vec<f32> = (0..LOGREG_D).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let x: Vec<f32> = (0..LOGREG_N * LOGREG_D).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let y: Vec<f32> = (0..LOGREG_N)
        .map(|i| {
            let dot: f32 = (0..LOGREG_D).map(|j| x[i * LOGREG_D + j] * w_true[j]).sum();
            if dot > 0.0 { 1.0 } else { 0.0 }
        })
        .collect();
    let mut w = vec![0.0f32; LOGREG_D];
    let mut losses = Vec::new();
    for _ in 0..25 {
        let (w2, loss) = rt.logreg_step(&w, &x, &y, 0.5).unwrap();
        w = w2;
        losses.push(loss);
    }
    assert!(losses[0] > 0.68 && losses[0] < 0.71, "initial loss ~ln2, got {}", losses[0]);
    assert!(
        losses.last().unwrap() < &(losses[0] * 0.5),
        "loss did not halve: {losses:?}"
    );
    assert_eq!(rt.executions.get(), 25);
}

#[test]
fn pagerank_converges_and_preserves_mass_through_pjrt() {
    let rt = runtime();
    let mut rng = Pcg::seeded(11);
    let n = PAGERANK_N;
    // Random link structure, column-normalized (transposed convention).
    let mut adj = vec![0.0f32; n * n];
    for c in 0..n {
        let mut outdeg = 0;
        for r in 0..n {
            if rng.chance(0.05) {
                adj[r * n + c] = 1.0;
                outdeg += 1;
            }
        }
        if outdeg == 0 {
            adj[c] = 1.0;
            outdeg = 1;
        }
        for r in 0..n {
            adj[r * n + c] /= outdeg as f32;
        }
    }
    let mut ranks = vec![1.0 / n as f32; n];
    let mut resid = f32::MAX;
    for _ in 0..40 {
        let (r2, res) = rt.pagerank_step(&adj, &ranks, 0.85).unwrap();
        ranks = r2;
        resid = res;
    }
    assert!(resid < 1e-4, "residual {resid}");
    let mass: f32 = ranks.iter().sum();
    assert!((mass - 1.0).abs() < 1e-3, "mass {mass}");
    assert!(ranks.iter().all(|&r| r > 0.0), "teleport keeps all ranks positive");
}

#[test]
fn wordcount_agg_counts_through_pjrt() {
    let rt = runtime();
    let mut rng = Pcg::seeded(13);
    let mut onehot = vec![0.0f32; SEG_N * SEG_K];
    let mut expected = vec![0.0f32; SEG_K];
    for i in 0..SEG_N {
        let k = rng.index(SEG_K);
        onehot[i * SEG_K + k] = 1.0;
        expected[k] += 1.0;
    }
    let values: Vec<f32> = (0..SEG_N * SEG_V).map(|i| if i % SEG_V == 0 { 1.0 } else { 0.5 }).collect();
    let out = rt.wordcount_agg(&onehot, &values).unwrap();
    assert_eq!(out.len(), SEG_K * SEG_V);
    for k in 0..SEG_K {
        assert!((out[k * SEG_V] - expected[k]).abs() < 1e-3, "count mismatch at {k}");
    }
}

#[test]
fn missing_artifacts_give_actionable_error() {
    match Runtime::load(std::path::Path::new("/nonexistent")) {
        Ok(_) => panic!("load should fail"),
        Err(err) => assert!(err.to_string().contains("make artifacts"), "{err}"),
    }
}
