//! Cross-deployment integration tests: paper-shape assertions, chaos
//! (spot revocations) survival, topology variations, determinism.

use houtu::config::{Config, Deployment};
use houtu::dag::{SizeClass, WorkloadKind};
use houtu::deploy::{run_single_job, run_trace_experiment, SingleJobPlan};
use houtu::ids::DcId;

fn cfg() -> Config {
    let mut c = Config::default();
    c.workload.num_jobs = 8;
    c
}

#[test]
fn paper_shape_houtu_beats_static_baselines() {
    let c = cfg();
    let houtu = run_trace_experiment(&c, Deployment::Houtu);
    let decent = run_trace_experiment(&c, Deployment::DecentStat);
    let cent_stat = run_trace_experiment(&c, Deployment::CentStat);
    // Fig 8 shape: houtu < decent-stat < ~cent-stat on avg JRT; makespan too.
    assert!(
        houtu.metrics.avg_jrt() < decent.metrics.avg_jrt(),
        "houtu {:.0} !< decent-stat {:.0}",
        houtu.metrics.avg_jrt(),
        decent.metrics.avg_jrt()
    );
    assert!(
        houtu.metrics.makespan() < cent_stat.metrics.makespan(),
        "houtu {:.0} !< cent-stat {:.0}",
        houtu.metrics.makespan(),
        cent_stat.metrics.makespan()
    );
}

#[test]
fn paper_shape_houtu_near_cent_dyna() {
    let c = cfg();
    let houtu = run_trace_experiment(&c, Deployment::Houtu);
    let dyna = run_trace_experiment(&c, Deployment::CentDyna);
    // §6.2: "approximate performance compared with the centralized
    // architecture with state-of-the-art dynamic scheduling".
    let ratio = houtu.metrics.avg_jrt() / dyna.metrics.avg_jrt();
    assert!(ratio < 1.15, "houtu/cent-dyna JRT ratio {ratio:.2}");
}

#[test]
fn paper_shape_spot_deployments_are_much_cheaper() {
    let c = Config::default(); // the calibrated 12-job trace
    let houtu = run_trace_experiment(&c, Deployment::Houtu);
    let cent_stat = run_trace_experiment(&c, Deployment::CentStat);
    // Fig 10: houtu machine cost way below the on-demand baseline.
    assert!(
        houtu.cost.machine_usd < cent_stat.cost.machine_usd * 0.5,
        "houtu ${:.2} vs cent-stat ${:.2}",
        houtu.cost.machine_usd,
        cent_stat.cost.machine_usd
    );
    // And it saves communication, not spends more.
    assert!(houtu.wan.stats.cross_dc_total_bytes() < cent_stat.wan.stats.cross_dc_total_bytes());
}

#[test]
fn survives_spot_revocation_chaos() {
    // Aggressive spot market: instances die mid-run; every job must still
    // complete through task re-queue + JM recovery.
    let mut c = cfg();
    c.workload.num_jobs = 6;
    c.cloud.revocations = true;
    c.cloud.spot_volatility = 0.6; // spiky market
    c.cloud.market_period_secs = 60.0;
    c.cloud.bid_multiplier = 1.3; // tight bids -> more revocations
    let w = run_trace_experiment(&c, Deployment::Houtu);
    assert_eq!(w.metrics.completed_jobs(), 6, "jobs lost to revocations");
    // Chaos must actually have happened for the test to mean anything.
    let recoveries = w.metrics.recovery_intervals_secs.len();
    let restarts: u32 = w.metrics.jobs.values().map(|j| j.restarts).sum();
    assert!(
        recoveries > 0 || restarts == 0,
        "expected JM recoveries under chaos (got {recoveries} recoveries, {restarts} restarts)"
    );
}

#[test]
fn chaos_versus_no_recovery_shows_the_mechanism_matters() {
    let mut c = cfg();
    c.workload.num_jobs = 6;
    c.cloud.revocations = true;
    c.cloud.spot_volatility = 0.6;
    c.cloud.market_period_secs = 60.0;
    c.cloud.bid_multiplier = 1.3;
    let with = run_trace_experiment(&c, Deployment::Houtu);
    // recovery_enabled=false degrades JM failures to full restarts.
    c.failures.recovery_enabled = false;
    let without = run_trace_experiment(&c, Deployment::Houtu);
    assert_eq!(with.metrics.completed_jobs(), 6);
    assert_eq!(without.metrics.completed_jobs(), 6);
    assert!(
        with.metrics.avg_jrt() <= without.metrics.avg_jrt() * 1.05,
        "recovery {:.0}s should not lose to restart {:.0}s",
        with.metrics.avg_jrt(),
        without.metrics.avg_jrt()
    );
}

#[test]
fn two_region_topology_works() {
    let mut c = cfg();
    c.topology.regions = vec!["A".into(), "B".into()];
    c.resize_bandwidth();
    c.workload.num_jobs = 4;
    for mode in [Deployment::Houtu, Deployment::CentStat] {
        let w = run_trace_experiment(&c, mode);
        assert_eq!(w.metrics.completed_jobs(), 4, "{mode:?}");
    }
}

#[test]
fn eight_region_topology_works() {
    let mut c = cfg();
    c.topology.regions = (0..8).map(|i| format!("R{i}")).collect();
    c.resize_bandwidth();
    c.workload.num_jobs = 4;
    let w = run_trace_experiment(&c, Deployment::Houtu);
    assert_eq!(w.metrics.completed_jobs(), 4);
    // 8 JM replicas per job.
    assert_eq!(w.jobs.values().next().unwrap().jms.len(), 8);
}

#[test]
fn deterministic_across_identical_runs_all_modes() {
    let c = cfg();
    for mode in Deployment::ALL {
        let a = run_trace_experiment(&c, mode);
        let b = run_trace_experiment(&c, mode);
        assert_eq!(a.metrics.avg_jrt(), b.metrics.avg_jrt(), "{mode:?}");
        assert_eq!(
            a.wan.stats.cross_dc_total_bytes(),
            b.wan.stats.cross_dc_total_bytes(),
            "{mode:?}"
        );
        assert_eq!(a.zk.stats.writes, b.zk.stats.writes, "{mode:?}");
    }
}

#[test]
fn different_seeds_give_different_schedules() {
    let mut c = cfg();
    let a = run_trace_experiment(&c, Deployment::Houtu);
    c.seed = 1234;
    let b = run_trace_experiment(&c, Deployment::Houtu);
    assert_ne!(a.metrics.avg_jrt(), b.metrics.avg_jrt());
}

#[test]
fn stealing_improves_injected_load_jrt() {
    let c = Config::default();
    let plan = || SingleJobPlan {
        kind: WorkloadKind::PageRank,
        size: SizeClass::Large,
        home: DcId(1),
        inject_at: Some((100.0, vec![DcId(0), DcId(2), DcId(3)])),
        kill_jm_at: None,
    };
    let with = run_single_job(&c, Deployment::Houtu, plan());
    let mut c2 = c.clone();
    c2.scheduler.work_stealing = false;
    let without = run_single_job(&c2, Deployment::Houtu, plan());
    let jrt = |w: &houtu::deploy::World| {
        w.metrics.jobs[&houtu::ids::JobId(0)].jrt().unwrap()
    };
    assert!(
        jrt(&with) < jrt(&without) * 0.9,
        "stealing {:.0}s !<< no-steal {:.0}s",
        jrt(&with),
        jrt(&without)
    );
}

#[test]
fn af_ablation_adaptive_releases_resources() {
    // Single small job on an empty cluster: with Af the job's containers
    // shrink back after stages drain; static holds them to the end.
    let c = Config::default();
    let w = run_single_job(
        &c,
        Deployment::Houtu,
        SingleJobPlan {
            kind: WorkloadKind::WordCount,
            size: SizeClass::Small,
            home: DcId(0),
            inject_at: None,
            kill_jm_at: None,
        },
    );
    // All pools fully restored after completion.
    for d in 0..4 {
        assert_eq!(
            w.cluster.free_pool(DcId(d)).len(),
            w.cluster.dc_capacity(DcId(d))
        );
    }
}

#[test]
fn zk_accumulates_replication_traffic() {
    let c = cfg();
    let w = run_trace_experiment(&c, Deployment::Houtu);
    assert!(w.zk.stats.writes > 100, "zk writes {}", w.zk.stats.writes);
    assert!(w.zk.stats.bytes_written > 10_000);
    assert!(w.wan.stats.cross_dc_control_bytes > 0, "control traffic accounted");
}

#[test]
fn killing_idle_node_is_harmless() {
    use houtu::deploy::{build_sim, kill_node};
    use houtu::ids::NodeId;
    use houtu::sim::secs;
    let c = cfg();
    let mut sim = build_sim(c, Deployment::Houtu, secs(100));
    kill_node(&mut sim, NodeId { dc: DcId(3), idx: 2 });
    sim.run_until(secs(100));
    // Node respawns after the re-acquisition delay.
    assert!(sim.state.cluster.node_alive(NodeId { dc: DcId(3), idx: 2 }));
    assert_eq!(sim.state.cluster.dc_capacity(DcId(3)), 16);
}

#[test]
fn double_jm_kill_still_recovers() {
    use houtu::dag::{SizeClass, WorkloadKind};
    use houtu::deploy::{build_sim, kill_jm_host, submit_job};
    use houtu::ids::JobId;
    use houtu::sim::{secs, secs_f};
    let c = cfg();
    let mut sim = build_sim(c, Deployment::Houtu, secs(14_400));
    sim.schedule_at(1, |sim| {
        submit_job(sim, WorkloadKind::WordCount, SizeClass::Large, DcId(0));
    });
    // Kill two different sJMs in quick succession.
    sim.schedule_at(secs_f(20.0), |sim| kill_jm_host(sim, JobId(0), DcId(1)));
    sim.schedule_at(secs_f(25.0), |sim| kill_jm_host(sim, JobId(0), DcId(3)));
    sim.run_until(secs(14_400));
    assert_eq!(sim.state.metrics.completed_jobs(), 1);
    assert!(sim.state.metrics.recovery_intervals_secs.len() >= 2);
}

#[test]
fn kill_pjm_then_new_pjm_too() {
    use houtu::dag::{SizeClass, WorkloadKind};
    use houtu::deploy::{build_sim, kill_jm_host, submit_job};
    use houtu::ids::JobId;
    use houtu::sim::{secs, secs_f};
    let c = cfg();
    let mut sim = build_sim(c, Deployment::Houtu, secs(14_400));
    sim.schedule_at(1, |sim| {
        submit_job(sim, WorkloadKind::WordCount, SizeClass::Large, DcId(0));
    });
    sim.schedule_at(secs_f(20.0), |sim| kill_jm_host(sim, JobId(0), DcId(0)));
    // After the election (primary moves), kill whoever is primary now.
    sim.schedule_at(secs_f(45.0), |sim| {
        let p = sim.state.jobs[&JobId(0)].primary;
        kill_jm_host(sim, JobId(0), p);
    });
    sim.run_until(secs(14_400));
    assert_eq!(sim.state.metrics.completed_jobs(), 1, "job must survive two elections");
    assert!(sim.state.metrics.election_delays_secs.len() >= 2);
}

#[test]
fn speculation_mitigates_stragglers() {
    // 25% of tasks run 6x slow; speculation should recover most of it.
    let mut c = cfg();
    c.workload.num_jobs = 6;
    c.workload.straggler_prob = 0.25;
    c.workload.straggler_factor = 6.0;
    c.failures.speculation = true;
    let with = run_trace_experiment(&c, Deployment::Houtu);
    c.failures.speculation = false;
    let without = run_trace_experiment(&c, Deployment::Houtu);
    assert_eq!(with.metrics.completed_jobs(), 6);
    assert_eq!(without.metrics.completed_jobs(), 6);
    let relaunches: u32 = with.jobs.values().map(|rt| rt.speculative_relaunches).sum();
    assert!(relaunches > 0, "stragglers present but nothing speculated");
    assert!(
        with.metrics.avg_jrt() < without.metrics.avg_jrt(),
        "speculation {:.0}s !< no-speculation {:.0}s",
        with.metrics.avg_jrt(),
        without.metrics.avg_jrt()
    );
}

#[test]
fn no_speculation_without_stragglers() {
    let c = cfg(); // straggler_prob = 0
    let w = run_trace_experiment(&c, Deployment::Houtu);
    let relaunches: u32 = w.jobs.values().map(|rt| rt.speculative_relaunches).sum();
    assert_eq!(relaunches, 0, "false-positive speculations");
}
