//! Differential wall for the World-as-parts campaign engine
//! (`deploy::parts` on `sim::shard::ShardedSim`).
//!
//! The wall pins one property from three directions: a campaign cell's
//! outcome on the parts engine is a pure function of `(spec, seed)` —
//! independent of the thread count, of how the conservative rounds
//! interleave across shards, and of reruns.
//!
//! 1. **Chaos × threaded.** Every chaos family `configs/campaign.toml`
//!    can express runs serial and threaded and must produce the same
//!    digest — including `kill_dc@` fired while the victim shard still
//!    has in-flight mailbox messages, which must drain deterministically
//!    (orphans re-homed by `ElectJm`, never dropped and never doubled).
//! 2. **Random topologies.** `forall_cases` draws topologies (2–6 DCs),
//!    workloads and chaos schedules and asserts interleaving invariance
//!    on each; a red run prints the offending case.
//! 3. **Replay lockstep.** Re-running any cell reproduces not just the
//!    digest but the whole counter row (events, tasks, steals,
//!    elections), i.e. replays execute in lockstep with the original.

use houtu::config::{Config, Deployment};
use houtu::dag::{SizeClass, WorkloadKind};
use houtu::deploy::{run_cell_on_parts, PartCell};
use houtu::ids::{DcId, NodeId};
use houtu::scenario::{ChaosEvent, ScenarioSpec, ScenarioWorkload};
use houtu::testkit::forall_cases;
use houtu::util::Pcg;

fn single(name: &str, size: SizeClass, home: usize, events: Vec<ChaosEvent>) -> ScenarioSpec {
    ScenarioSpec {
        name: name.to_string(),
        deployment: Deployment::Houtu,
        regions: 0,
        workload: ScenarioWorkload::SingleJob {
            kind: WorkloadKind::PageRank,
            size,
            home: DcId(home),
        },
        events,
        overrides: vec![],
    }
}

/// Run one cell serial and at 2 and 4 threads; every observable except
/// wall time must be bit-identical. Returns the serial cell for further
/// assertions. (`peak` is deliberately excluded: queue depth is a
/// per-shard-layout metric, not part of the replay contract.)
fn pin_thread_invariant(spec: &ScenarioSpec, seed: u64) -> PartCell {
    let base = Config::default();
    let serial = run_cell_on_parts(&base, spec, seed, 1)
        .unwrap_or_else(|e| panic!("{}/seed{seed}: {e}", spec.name));
    assert!(serial.events > 0, "{}/seed{seed}: empty run", spec.name);
    assert_ne!(serial.digest, 0, "{}/seed{seed}: degenerate digest", spec.name);
    for threads in [2usize, 4] {
        let t = run_cell_on_parts(&base, spec, seed, threads)
            .unwrap_or_else(|e| panic!("{}/seed{seed}/t{threads}: {e}", spec.name));
        assert_eq!(
            format!("{:016x}", serial.digest),
            format!("{:016x}", t.digest),
            "{}/seed{seed}: digest diverged at {threads} threads",
            spec.name
        );
        assert_eq!(
            (serial.events, serial.tasks_run, serial.steals, serial.elections, serial.jobs_done),
            (t.events, t.tasks_run, t.steals, t.elections, t.jobs_done),
            "{}/seed{seed}: counters diverged at {threads} threads",
            spec.name
        );
    }
    serial
}

/// `kill_dc@` lands while the home shard has in-flight mailbox traffic
/// (replication to peers, steal requests, WAN-delayed task returns): the
/// drain must be deterministic at every thread count, the orphaned job
/// must be re-homed by election — not lost — and the run must still
/// complete the job.
#[test]
fn kill_dc_drains_in_flight_mailboxes_deterministically() {
    // A Large job fans 64 tasks over 6 stages, so at t=5 s the home DC
    // has outstanding steals and task returns on the wire. Killing dc1
    // then — and its revival 60 s later — exercises the orphan handoff
    // while messages addressed to the dead part are still in flight.
    let spec = single(
        "kill-dc-midflight",
        SizeClass::Large,
        1,
        vec![ChaosEvent::KillDc { at_secs: 5.0, dc: DcId(1) }],
    );
    let mut rows = Vec::new();
    for seed in [42u64, 7, 1234] {
        let cell = pin_thread_invariant(&spec, seed);
        assert_eq!(cell.jobs_done, 1, "seed{seed}: the orphaned job must still finish");
        assert!(cell.elections > 0, "seed{seed}: the kill must force an election");
        rows.push(cell);
    }
    // Replay lockstep: the same cell a second time reproduces the whole
    // row, not just the digest.
    let again = run_cell_on_parts(&Config::default(), &spec, 42, 4).unwrap();
    assert_eq!(rows[0].digest, again.digest, "rerun must replay in lockstep");
    assert_eq!(rows[0].events, again.events);
    assert_eq!(rows[0].tasks_run, again.tasks_run);
    // Seeds must actually move the stream (the digest sees the run).
    assert_ne!(rows[0].digest, rows[1].digest, "seed collision");
    assert_ne!(rows[1].digest, rows[2].digest, "seed collision");
}

/// Every chaos family the campaign DSL knows, serial vs threaded: the
/// cross-shard messages each family generates (hog clamps, elections,
/// cascading kills, node churn, whole-DC drains, storm windows, WAN
/// rescales on all-pairs and single pairs) are all interleaving
/// invariant.
#[test]
fn every_chaos_family_pins_serial_vs_threaded() {
    let families = vec![
        single(
            "hogs",
            SizeClass::Medium,
            1,
            vec![ChaosEvent::InjectHogs {
                at_secs: 10.0,
                dcs: vec![DcId(0), DcId(2), DcId(3)],
            }],
        ),
        single(
            "kill-jm",
            SizeClass::Medium,
            0,
            vec![ChaosEvent::KillJm { at_secs: 70.0, dc: DcId(0) }],
        ),
        single(
            "jm-cascade",
            SizeClass::Large,
            0,
            vec![ChaosEvent::KillJmCascade {
                at_secs: 70.0,
                dc: DcId(0),
                count: 2,
                gap_secs: 45.0,
            }],
        ),
        single(
            "kill-node",
            SizeClass::Medium,
            1,
            vec![ChaosEvent::KillNode {
                at_secs: 40.0,
                node: NodeId { dc: DcId(1), idx: 0 },
            }],
        ),
        single(
            "dc-outage",
            SizeClass::Large,
            0,
            vec![ChaosEvent::KillDc { at_secs: 70.0, dc: DcId(2) }],
        ),
        single(
            "spot-storm",
            SizeClass::Medium,
            1,
            vec![ChaosEvent::SpotStorm {
                at_secs: 20.0,
                dc: DcId(1),
                dur_secs: 120.0,
                sigma_factor: 3.0,
            }],
        ),
        single(
            "wan-degrade",
            SizeClass::Medium,
            0,
            vec![ChaosEvent::WanDegrade { from_secs: 30.0, until_secs: 120.0, factor: 0.1 }],
        ),
        single(
            "wan-pair",
            SizeClass::Medium,
            0,
            vec![
                ChaosEvent::WanPairDegrade {
                    at_secs: 30.0,
                    a: DcId(0),
                    b: DcId(2),
                    factor: 0.05,
                },
                ChaosEvent::WanPairDegrade {
                    at_secs: 120.0,
                    a: DcId(0),
                    b: DcId(2),
                    factor: 1.0,
                },
            ],
        ),
    ];
    let mut digests = Vec::new();
    for spec in &families {
        for seed in [42u64, 7] {
            let cell = pin_thread_invariant(spec, seed);
            assert!(cell.jobs_done >= 1, "{}/seed{seed}: job lost to chaos", spec.name);
            if seed == 42 {
                digests.push(cell.digest);
            }
        }
    }
    // The chaos is not cosmetic: every family perturbs the stream away
    // from every other (all 8 digests distinct at the shared seed).
    let mut uniq = digests.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), digests.len(), "two chaos families produced identical streams");
}

/// Build a generated-topology cell with a two-tier boundary.
fn tiered(
    name: &str,
    total: usize,
    exact: usize,
    workload: ScenarioWorkload,
    events: Vec<ChaosEvent>,
) -> ScenarioSpec {
    ScenarioSpec {
        name: name.to_string(),
        deployment: Deployment::Houtu,
        regions: 0,
        workload,
        events,
        overrides: vec![
            format!("topology.generated=generated:{total},4,7"),
            format!("topology.exact_dcs={exact}"),
        ],
    }
}

/// The two-tier invariance pin: a job that never leaves the exact tier
/// digests bit-identically whether the generated world carries 0 or 200
/// background DCs. Background parts stay dormant (zero events), the
/// exact tier's WAN inputs are prefix-stable (`houtu::topo`), and the
/// cell digest folds active parts only — so the *whole observable row*
/// (digest, events, tasks, jobs) must match, not just survive. `peak`
/// is excluded: queue capacity is a function of the part count.
#[test]
fn background_dcs_do_not_perturb_the_exact_tier() {
    let mk = |total: usize| {
        tiered(
            "bg-invariance",
            total,
            4,
            ScenarioWorkload::Trace { num_jobs: 3 },
            vec![ChaosEvent::SpotStorm {
                at_secs: 20.0,
                dc: DcId(1),
                dur_secs: 90.0,
                sigma_factor: 2.5,
            }],
        )
    };
    let base = Config::default();
    for seed in [42u64, 7] {
        let small = run_cell_on_parts(&base, &mk(4), seed, 1)
            .unwrap_or_else(|e| panic!("4dc/seed{seed}: {e}"));
        let big = run_cell_on_parts(&base, &mk(204), seed, 1)
            .unwrap_or_else(|e| panic!("204dc/seed{seed}: {e}"));
        assert!(small.jobs_done > 0, "seed{seed}: no job finished");
        assert_eq!(
            format!("{:016x}", small.digest),
            format!("{:016x}", big.digest),
            "seed{seed}: 200 dormant background DCs moved the exact tier's digest"
        );
        assert_eq!(
            (small.events, small.tasks_run, small.jobs_done),
            (big.events, big.tasks_run, big.jobs_done),
            "seed{seed}: background DCs moved the exact tier's counters"
        );
    }
}

/// Dynamic promotion: `kill_dc@` targeting a *background* DC of a
/// 16-DC world (exact tier = 4) promotes it mid-run. The promotion —
/// price-walk catch-up from the part's own untouched stream, one
/// transition fold, market ticks from then on — must be deterministic
/// and serial ≡ threaded, and it must visibly change the stream
/// relative to the no-kill twin (the promoted part now participates in
/// the digest).
#[test]
fn promoting_a_background_dc_mid_run_is_deterministic() {
    let job = ScenarioWorkload::SingleJob {
        kind: WorkloadKind::PageRank,
        size: SizeClass::Medium,
        home: DcId(1),
    };
    let kill = tiered(
        "bg-promote",
        16,
        4,
        job.clone(),
        vec![ChaosEvent::KillDc { at_secs: 30.0, dc: DcId(10) }],
    );
    let calm = tiered("bg-calm", 16, 4, job, vec![]);
    for seed in [42u64, 7] {
        let k = pin_thread_invariant(&kill, seed);
        assert_eq!(k.jobs_done, 1, "seed{seed}: killing a background DC must not hurt the job");
        let c = pin_thread_invariant(&calm, seed);
        assert_ne!(
            k.digest, c.digest,
            "seed{seed}: promoting dc10 left no trace in the stream"
        );
        assert!(k.events > c.events, "seed{seed}: the promoted part processed no events");
    }
}

/// Static promotion: a `SingleJob` homed *outside* the boundary widens
/// the exact tier at cell setup (the promotion rule applied statically),
/// so the job still runs the full protocol and completes, thread-count
/// invariantly.
#[test]
fn a_job_homed_beyond_the_boundary_widens_the_exact_tier() {
    let spec = tiered(
        "bg-home-outside",
        16,
        4,
        ScenarioWorkload::SingleJob {
            kind: WorkloadKind::WordCount,
            size: SizeClass::Small,
            home: DcId(10),
        },
        vec![],
    );
    let cell = pin_thread_invariant(&spec, 42);
    assert_eq!(cell.jobs_done, 1, "the out-of-tier job must finish");
    assert!(cell.tasks_run > 0);
}

/// Property wall: random topologies (2–6 DCs), random workloads and a
/// random chaos schedule — each drawn case must be thread-count
/// invariant and replay in lockstep. The kit prints the failing case.
#[test]
fn random_cells_are_interleaving_invariant_and_replay_lockstep() {
    let gen = |rng: &mut Pcg| {
        let ndc = 2 + rng.index(5); // 2..=6 DCs
        let seed = rng.below(1 << 40);
        let workload = if rng.chance(0.5) {
            ScenarioWorkload::SingleJob {
                kind: [
                    WorkloadKind::WordCount,
                    WorkloadKind::TpcH,
                    WorkloadKind::IterativeMl,
                    WorkloadKind::PageRank,
                ][rng.index(4)],
                size: [SizeClass::Small, SizeClass::Medium][rng.index(2)],
                home: DcId(rng.index(ndc)),
            }
        } else {
            ScenarioWorkload::Trace { num_jobs: 1 + rng.index(4) }
        };
        let at_secs = 5.0 + rng.below(120) as f64;
        let dc = DcId(rng.index(ndc));
        let event = match rng.index(6) {
            0 => ChaosEvent::InjectHogs { at_secs, dcs: vec![dc] },
            1 => ChaosEvent::KillDc { at_secs, dc },
            2 => ChaosEvent::KillJm { at_secs, dc },
            3 => ChaosEvent::KillNode { at_secs, node: NodeId { dc, idx: rng.index(4) } },
            4 => ChaosEvent::SpotStorm { at_secs, dc, dur_secs: 90.0, sigma_factor: 2.5 },
            _ => ChaosEvent::WanDegrade {
                from_secs: at_secs,
                until_secs: at_secs + 60.0,
                factor: 0.2,
            },
        };
        let events = if rng.chance(0.8) { vec![event] } else { vec![] };
        let spec = ScenarioSpec {
            name: format!("rand-{ndc}dc"),
            deployment: Deployment::Houtu,
            regions: ndc,
            workload,
            events,
            overrides: vec![],
        };
        (spec, seed)
    };
    forall_cases(23, 12, &gen, |(spec, seed): &(ScenarioSpec, u64)| {
        let base = Config::default();
        let serial = run_cell_on_parts(&base, spec, *seed, 1)
            .map_err(|e| format!("serial run failed: {e}"))?;
        if serial.events == 0 {
            return Err("empty run".to_string());
        }
        for threads in [2usize, 4] {
            let t = run_cell_on_parts(&base, spec, *seed, threads)
                .map_err(|e| format!("{threads}-thread run failed: {e}"))?;
            if t.digest != serial.digest {
                return Err(format!(
                    "digest {:016x} != serial {:016x} at {threads} threads",
                    t.digest, serial.digest
                ));
            }
            if (t.events, t.tasks_run, t.jobs_done)
                != (serial.events, serial.tasks_run, serial.jobs_done)
            {
                return Err(format!("counters diverged at {threads} threads"));
            }
        }
        let again = run_cell_on_parts(&base, spec, *seed, 2)
            .map_err(|e| format!("rerun failed: {e}"))?;
        if (again.digest, again.events) != (serial.digest, serial.events) {
            return Err("rerun did not replay in lockstep".to_string());
        }
        Ok(())
    });
}
