//! Golden replay-digest pin for the sim-core queue swap.
//!
//! The digest of a run is an order-sensitive fold of its *entire* trace
//! stream, so it is the strongest replay check the repo has. This suite
//! pins every cell of `standard_campaign()` (10 scenarios × 3 seeds) two
//! ways:
//!
//! 1. **Executable golden record.** The pre-swap queue engine is vendored
//!    in-tree ([`QueueKind::Legacy`], byte-for-byte the old
//!    `BinaryHeap` + tombstone-set implementation), so "record the digest
//!    before the swap" is executed *at test time*: every cell runs on
//!    both engines and the digests must match bit-identically. Unlike a
//!    hardcoded table, this pin cannot go stale against the thing it is
//!    meant to guard (the queue overhaul), and it re-proves the swap on
//!    every CI run.
//! 2. **Optional static table.** If `rust/tests/golden_digests.json`
//!    exists, every cell digest must also match it — catching *any*
//!    future behavioral drift, queue-related or not. Regenerate it (after
//!    auditing the drift is intentional) with:
//!    `HOUTU_PIN_GOLDEN=1 cargo test --test golden_digests`.
//! 3. **Sharded-engine pin.** Every cell also runs on
//!    [`QueueKind::Sharded`] at 1, 2 and 4 shards and must reproduce the
//!    slab digests bit-identically — the determinism gate for the
//!    per-DC sharded queue (`houtu campaign --shards N`).
//! 4. **Parts-engine wall.** Every cell also runs on the World-as-parts
//!    model (`houtu campaign --engine sharded-sim`), where DC state is
//!    split into `Send` parts and all cross-DC interaction is
//!    message-shaped. That engine has its *own* digest (the sequential
//!    World's trace stream cannot be compared bit-for-bit against a
//!    differently-factored state model), so its wall is internal:
//!    serial, 2-thread and 4-thread executions of every cell must be
//!    bit-identical.

use houtu::config::Config;
use houtu::deploy::run_cell_on_parts;
use houtu::scenario::runner::par_map;
use houtu::scenario::{
    run_digest, run_scenario_on, smoke_campaign, standard_campaign,
};
use houtu::sim::QueueKind;
use houtu::util::json::{self, Json};

#[derive(Debug, Clone, PartialEq)]
struct CellPin {
    scenario: String,
    seed: u64,
    digest: u64,
    events: u64,
}

fn compute_pins(queue: QueueKind) -> Vec<CellPin> {
    let base = Config::default();
    let cells = standard_campaign().expand();
    let workers =
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(cells.len());
    par_map(workers, cells.len(), |i| {
        let (sc, seed) = &cells[i];
        let run = run_scenario_on(&base, sc, *seed, queue)
            .unwrap_or_else(|e| panic!("{}/seed{}: {e}", sc.name, seed));
        CellPin {
            scenario: sc.name.clone(),
            seed: *seed,
            digest: run_digest(&run),
            events: run.events_processed,
        }
    })
}

fn golden_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden_digests.json")
}

fn pins_to_json(pins: &[CellPin]) -> String {
    let mut out = String::new();
    out.push_str("{\n  \"campaign\": \"reliability-matrix\",\n  \"cells\": [\n");
    for (i, p) in pins.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"scenario\": {}, \"seed\": {}, \"digest\": \"{:016x}\", \"events\": {}}}{}\n",
            json::escape(&p.scenario),
            p.seed,
            p.digest,
            p.events,
            if i + 1 == pins.len() { "" } else { "," }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

fn check_against_static_table(pins: &[CellPin]) {
    let path = golden_path();
    if std::env::var("HOUTU_PIN_GOLDEN").is_ok() {
        std::fs::write(&path, pins_to_json(pins)).expect("writing golden table");
        eprintln!("golden_digests: wrote {} cells to {}", pins.len(), path.display());
        return;
    }
    let Ok(text) = std::fs::read_to_string(&path) else {
        // No static table committed yet — the executable legacy-queue pin
        // above is the authoritative record. Generate the table with
        // HOUTU_PIN_GOLDEN=1 once a maintainer wants hard values too.
        return;
    };
    let doc = json::parse(&text).expect("golden table must be valid json");
    let cells = doc.get("cells").and_then(Json::as_array).expect("golden table cells");
    assert_eq!(cells.len(), pins.len(), "golden table cell count drifted");
    for (j, p) in cells.iter().zip(pins) {
        let scenario = j.get("scenario").and_then(Json::as_str).expect("scenario");
        let seed = j.get("seed").and_then(Json::as_u64).expect("seed");
        let digest = j.get("digest").and_then(Json::as_str).expect("digest");
        assert_eq!((scenario, seed), (p.scenario.as_str(), p.seed), "cell order drifted");
        assert_eq!(
            digest,
            format!("{:016x}", p.digest),
            "{}/seed{}: replay digest drifted from the committed golden table \
             (audit the change, then re-pin with HOUTU_PIN_GOLDEN=1)",
            p.scenario,
            p.seed
        );
    }
}

/// The tentpole acceptance gate: all 30 standard-campaign cells replay
/// bit-identically on the pre-swap queue and the slab queue.
#[test]
fn standard_campaign_digests_survive_the_queue_swap() {
    let slab = compute_pins(QueueKind::Slab);
    let legacy = compute_pins(QueueKind::Legacy);
    assert_eq!(slab.len(), 30, "expected the 10×3 standard matrix");
    assert_eq!(slab.len(), legacy.len());
    for (a, b) in slab.iter().zip(&legacy) {
        assert_eq!(
            (&a.scenario, a.seed),
            (&b.scenario, b.seed),
            "cell order must be engine-independent"
        );
        assert_eq!(
            format!("{:016x}", a.digest),
            format!("{:016x}", b.digest),
            "{}/seed{}: replay digest drifted across the queue swap",
            a.scenario,
            a.seed
        );
        assert_eq!(
            a.events, b.events,
            "{}/seed{}: event count drifted across the queue swap",
            a.scenario,
            a.seed
        );
        assert_ne!(a.digest, 0, "{}/seed{}: degenerate digest", a.scenario, a.seed);
        assert!(a.events > 0, "{}/seed{}: empty run", a.scenario, a.seed);
    }
    // Digests must be informative: within every scenario, the three
    // seeds produce three distinct streams.
    for chunk in slab.chunks(3) {
        assert!(
            chunk[0].digest != chunk[1].digest
                && chunk[1].digest != chunk[2].digest
                && chunk[0].digest != chunk[2].digest,
            "{}: seeds collided — digest is not seeing the stream",
            chunk[0].scenario
        );
    }
    check_against_static_table(&slab);
}

/// The sharded-engine acceptance gate: all 30 standard-campaign cells
/// replay bit-identically on the sharded queue — and the result is
/// invariant to the shard count (1, 2 and 4 shards), because the n-way
/// merge restores the exact global `(time, seq)` order no matter how
/// events were routed across sub-queues.
/// The parts-engine wall (`--engine sharded-sim`): all 30
/// standard-campaign cells replay bit-identically on the World-as-parts
/// model whether the ShardedSim rounds execute serially or on 2 or 4
/// worker threads. Event counts and completion counters must match too,
/// so a thread-sensitive stray (a dropped mailbox message, a double
/// delivery) cannot hide behind a lucky hash.
#[test]
fn standard_campaign_parts_digests_are_thread_count_invariant() {
    let base = Config::default();
    let cells = standard_campaign().expand();
    assert_eq!(cells.len(), 30, "expected the 10×3 standard matrix");
    let workers =
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(cells.len());
    let serial = par_map(workers, cells.len(), |i| {
        let (sc, seed) = &cells[i];
        run_cell_on_parts(&base, sc, *seed, 1)
            .unwrap_or_else(|e| panic!("{}/seed{}: {e}", sc.name, seed))
    });
    for threads in [2usize, 4] {
        // Threaded cells run one at a time: each already spawns its own
        // shard workers, and the wall must observe *their* interleaving.
        for (i, (sc, seed)) in cells.iter().enumerate() {
            let t = run_cell_on_parts(&base, sc, *seed, threads)
                .unwrap_or_else(|e| panic!("{}/seed{}/t{threads}: {e}", sc.name, seed));
            let s = &serial[i];
            assert_eq!(
                format!("{:016x}", s.digest),
                format!("{:016x}", t.digest),
                "{}/seed{}: parts digest diverged at {threads} threads",
                sc.name,
                seed
            );
            assert_eq!(
                (s.events, s.tasks_run, s.jobs_done),
                (t.events, t.tasks_run, t.jobs_done),
                "{}/seed{}: parts counters diverged at {threads} threads",
                sc.name,
                seed
            );
        }
    }
    for s in &serial {
        assert!(s.events > 0, "{}/seed{}: empty parts run", s.scenario, s.seed);
        assert!(s.jobs_done > 0, "{}/seed{}: no job finished", s.scenario, s.seed);
        assert_ne!(s.digest, 0, "{}/seed{}: degenerate digest", s.scenario, s.seed);
    }
    // Seeds must move the parts stream exactly as they move the World's.
    for chunk in serial.chunks(3) {
        assert!(
            chunk[0].digest != chunk[1].digest
                && chunk[1].digest != chunk[2].digest
                && chunk[0].digest != chunk[2].digest,
            "{}: seeds collided on the parts engine",
            chunk[0].scenario
        );
    }
}

/// Queue-depth regression for the sharded queue (`--shards N`): the
/// engines execute the identical event stream, so the high-water mark
/// [`houtu::scenario::FinishedRun::peak_pending`] reports must agree
/// between the sequential slab queue and the sharded queue at any shard
/// count — the sharded engine tracks *live* global depth, not per-shard
/// fragments.
#[test]
fn smoke_campaign_peak_pending_is_engine_invariant() {
    let base = Config::default();
    for (sc, seed) in smoke_campaign().expand() {
        let slab = run_scenario_on(&base, &sc, seed, QueueKind::Slab)
            .unwrap_or_else(|e| panic!("{}/seed{seed}: {e}", sc.name));
        assert!(slab.peak_pending > 0, "{}/seed{seed}: depth never rose", sc.name);
        for shards in [2usize, 4] {
            let sharded = run_scenario_on(&base, &sc, seed, QueueKind::Sharded(shards))
                .unwrap_or_else(|e| panic!("{}/seed{seed}: {e}", sc.name));
            assert_eq!(
                slab.peak_pending, sharded.peak_pending,
                "{}/seed{seed}: peak queue depth drifted at {shards} shards",
                sc.name
            );
        }
    }
}

/// The SoA node-store wall: all 30 standard-campaign cells replay
/// bit-identically with the legacy per-node mirror enabled. With shadow
/// checking on, every cluster build and every node mutation
/// (kill/restart/class change) is cross-checked field-by-field against
/// an array-of-structs replica, so the columnar store cannot silently
/// drift from the layout it replaced — and because the mirror only adds
/// assertions, the digests themselves must not move either.
#[test]
fn standard_campaign_digests_survive_the_soa_node_store() {
    let plain = compute_pins(QueueKind::Slab);
    assert_eq!(plain.len(), 30, "expected the 10×3 standard matrix");
    houtu::cluster::set_shadow_check(true);
    let shadowed = compute_pins(QueueKind::Slab);
    houtu::cluster::set_shadow_check(false);
    assert_eq!(plain.len(), shadowed.len());
    for (a, b) in plain.iter().zip(&shadowed) {
        assert_eq!(
            (&a.scenario, a.seed),
            (&b.scenario, b.seed),
            "cell order must not depend on shadow checking"
        );
        assert_eq!(
            format!("{:016x}", a.digest),
            format!("{:016x}", b.digest),
            "{}/seed{}: replay digest drifted under the SoA shadow mirror",
            a.scenario,
            a.seed
        );
        assert_eq!(
            a.events, b.events,
            "{}/seed{}: event count drifted under the SoA shadow mirror",
            a.scenario,
            a.seed
        );
    }
}

#[test]
fn standard_campaign_digests_are_shard_count_invariant() {
    let slab = compute_pins(QueueKind::Slab);
    assert_eq!(slab.len(), 30, "expected the 10×3 standard matrix");
    for shards in [1usize, 2, 4] {
        let sharded = compute_pins(QueueKind::Sharded(shards));
        assert_eq!(slab.len(), sharded.len());
        for (a, b) in slab.iter().zip(&sharded) {
            assert_eq!(
                (&a.scenario, a.seed),
                (&b.scenario, b.seed),
                "cell order must be engine-independent"
            );
            assert_eq!(
                format!("{:016x}", a.digest),
                format!("{:016x}", b.digest),
                "{}/seed{}: replay digest drifted on the sharded queue ({shards} shards)",
                a.scenario,
                a.seed
            );
            assert_eq!(
                a.events, b.events,
                "{}/seed{}: event count drifted on the sharded queue ({shards} shards)",
                a.scenario,
                a.seed
            );
        }
    }
}
