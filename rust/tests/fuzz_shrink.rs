//! Chaos-fuzzer shrinking: a seeded known-bad cell must shrink to a
//! minimal schedule deterministically, the emitted repro TOML must
//! round-trip through `CampaignSpec` parsing bit-exactly, and the fuzz
//! report's JSON serialization must survive the `util::json` edge cases
//! (escaped strings, deep nesting, NaN/Inf rejection).

use houtu::config::Config;
use houtu::scenario::fuzz::{
    repro_toml, run_fuzz_with, verify_report_json, write_repro, CellGen, CellOutcome, FuzzOpts,
    FuzzReport, FuzzSpace,
};
use houtu::scenario::{CampaignSpec, ChaosEvent, ScenarioSpec, ScenarioWorkload};
use houtu::testkit::Gen;
use houtu::util::json::{self, Json};
use houtu::util::Pcg;

fn is_kill(ev: &ChaosEvent) -> bool {
    matches!(
        ev,
        ChaosEvent::KillJm { .. }
            | ChaosEvent::KillJmCascade { .. }
            | ChaosEvent::KillNode { .. }
            | ChaosEvent::KillDc { .. }
    )
}

/// Synthetic bug: any schedule containing a kill-family event "fails".
/// The minimal counterexample is therefore exactly one kill event at t=0
/// with every other axis collapsed to its simplest value.
fn kill_oracle(_base: &Config, spec: &ScenarioSpec, _seed: u64) -> CellOutcome {
    let bad = spec.events.iter().any(is_kill);
    CellOutcome {
        violations: if bad { vec!["synthetic: kill events break this tree".into()] } else { vec![] },
        digest: spec.events.len() as u64,
        usd: 0.0,
    }
}

fn fuzz_kill_bug(seed: u64) -> FuzzReport {
    let base = Config::default();
    let opts = FuzzOpts { cases: 48, seed, parallelism: 2, max_shrink_iters: 2000 };
    run_fuzz_with(&base, &FuzzSpace::default(), &opts, &kill_oracle)
}

/// Scan a few fixed fuzz seeds for a deterministic known-bad sample.
/// Generation is seeded, so this never flakes: the same seeds yield the
/// same cells on every run.
fn known_bad_report() -> FuzzReport {
    for seed in 1u64..6 {
        let rep = fuzz_kill_bug(seed);
        if !rep.failures.is_empty() {
            return rep;
        }
    }
    panic!("240 sampled cells never drew a kill-family event");
}

#[test]
fn known_bad_cell_shrinks_to_minimal_schedule_deterministically() {
    let rep = known_bad_report();
    let again = fuzz_kill_bug(rep.seed);
    assert_eq!(rep.failures.len(), again.failures.len(), "shrinking is not deterministic");
    for (a, b) in rep.failures.iter().zip(&again.failures) {
        assert_eq!(a.shrunk, b.shrunk, "same cell shrank to different minima");
        assert_eq!(a.shrink_steps, b.shrink_steps);
    }
    for f in &rep.failures {
        let s = &f.shrunk.spec;
        // Minimal schedule: exactly one event, and it is the guilty kind.
        assert_eq!(s.events.len(), 1, "not minimal: {:?}", s.events);
        assert!(is_kill(&s.events[0]), "shrunk to an innocent event: {}", s.events[0]);
        // Every other axis collapsed.
        let at = match &s.events[0] {
            ChaosEvent::KillJm { at_secs, .. }
            | ChaosEvent::KillJmCascade { at_secs, .. }
            | ChaosEvent::KillNode { at_secs, .. }
            | ChaosEvent::KillDc { at_secs, .. } => *at_secs,
            other => panic!("unexpected event {other}"),
        };
        assert_eq!(at, 0.0, "time not minimized: {}", s.events[0]);
        assert!(s.overrides.is_empty(), "overrides not dropped: {:?}", s.overrides);
        assert_eq!(s.regions, 0, "regions not collapsed");
        assert_eq!(f.shrunk.seed, 1, "seed not shrunk");
        match s.workload {
            ScenarioWorkload::Trace { num_jobs } => assert_eq!(num_jobs, 1),
            ScenarioWorkload::SingleJob { size, home, .. } => {
                assert_eq!(size, houtu::dag::SizeClass::Small);
                assert_eq!(home, houtu::ids::DcId(0));
            }
        }
    }
}

#[test]
fn emitted_repro_toml_round_trips_bit_exactly() {
    let rep = known_bad_report();
    let f = &rep.failures[0];
    // In-memory: parse the repro text straight back.
    let text = repro_toml(&f.shrunk);
    let doc = houtu::config::toml::parse(&text).unwrap();
    let spec = CampaignSpec::from_doc(&doc).unwrap();
    assert_eq!(spec.seeds, vec![f.shrunk.seed]);
    assert_eq!(spec.scenarios.len(), 1);
    assert_eq!(spec.scenarios[0], f.shrunk.spec, "repro drifted:\n{text}");
    // Through the filesystem: `write_repro` asserts the same round-trip
    // on the actual artifact `houtu campaign --spec` would load.
    let path = std::env::temp_dir().join("houtu_fuzz_repro_test.toml");
    let path = path.to_str().unwrap();
    write_repro(&f.shrunk, path).unwrap();
    // And the repro still reproduces the violation under the same oracle.
    let back = CampaignSpec::from_file(path).unwrap();
    let out = kill_oracle(&Config::default(), &back.scenarios[0], back.seeds[0]);
    assert!(!out.violations.is_empty(), "minimized repro no longer fails");
    let _ = std::fs::remove_file(path);
}

#[test]
fn repro_toml_round_trips_across_the_sampled_space() {
    // Not just shrunk minima: arbitrary sampled cells (all families, all
    // axes) must survive TOML emission + parsing bit-exactly, floats
    // included (Rust float Display is shortest-round-trip).
    let base = Config::default();
    let space = FuzzSpace::default();
    let gen = CellGen::new(&space, &base);
    let mut rng = Pcg::new(77, 0xf0_22);
    for _ in 0..80 {
        let cell = gen.generate(&mut rng);
        let text = repro_toml(&cell);
        let doc = houtu::config::toml::parse(&text)
            .unwrap_or_else(|e| panic!("unparseable repro: {e}\n{text}"));
        let spec = CampaignSpec::from_doc(&doc).unwrap();
        assert_eq!(spec.seeds, vec![cell.seed], "{text}");
        assert_eq!(spec.scenarios[0], cell.spec, "{text}");
    }
}

/// A report with adversarial strings: quotes, backslashes, newlines,
/// tabs, control characters and non-ASCII must all survive the JSON
/// writer + `util::json` parser round-trip.
#[test]
fn fuzz_report_json_survives_escaped_strings() {
    let rep = known_bad_report();
    let mut doctored = rep.clone();
    doctored.failures[0].violations = vec![
        "quote \" backslash \\ done".to_string(),
        "newline\nand\ttab".to_string(),
        "ctrl:\u{1} bell:\u{7} unicode: héllo — ✓".to_string(),
    ];
    let text = doctored.to_json();
    verify_report_json(&doctored, &text).unwrap();
    // Check one escape survived through the real parser, not just our
    // validator.
    let doc = json::parse(&text).unwrap();
    let failures = doc.get("failures").and_then(Json::as_array).unwrap();
    let viol = failures[0].get("violations").and_then(Json::as_array).unwrap();
    assert_eq!(viol[0].as_str(), Some("quote \" backslash \\ done"));
    assert_eq!(viol[1].as_str(), Some("newline\nand\ttab"));
    // The embedded repro TOML (a multi-line document with quotes) is the
    // heaviest escape payload; it must come back byte-identical.
    let toml_text = failures[0].get("repro_toml").and_then(Json::as_str).unwrap();
    assert_eq!(toml_text, repro_toml(&doctored.failures[0].shrunk));
    assert!(toml_text.contains('\n') && toml_text.contains('"'));
}

#[test]
fn fuzz_report_json_round_trips_clean_and_failing_reports() {
    // Clean report (no failures) — the common CI path.
    let clean = FuzzReport {
        seed: 1,
        cases: 3,
        workers: 2,
        case_digests: vec![0xdead_beef_0000_0001, 7, u64::MAX],
        case_usd: vec![0.25, 0.0, 1.5],
        failures: vec![],
        wall_ms: 12,
    };
    verify_report_json(&clean, &clean.to_json()).unwrap();
    // Failing report straight from the fuzzer.
    let rep = known_bad_report();
    verify_report_json(&rep, &rep.to_json()).unwrap();
    // Through the filesystem: the `houtu fuzz --report` path.
    let path = std::env::temp_dir().join("houtu_fuzz_report_test.json");
    let path = path.to_str().unwrap();
    houtu::scenario::fuzz::write_report(&rep, path).unwrap();
    let _ = std::fs::remove_file(path);
    assert!(
        houtu::scenario::fuzz::write_report(&rep, "/tmp/fuzz_report.csv").is_err(),
        "only .json is a valid fuzz report format"
    );
    // Tampering is detected.
    let mut other = rep.clone();
    other.case_digests[0] ^= 1;
    assert!(verify_report_json(&other, &rep.to_json()).is_err());
}

#[test]
fn json_parser_handles_deep_nesting() {
    // 120 levels of arrays with one scalar at the bottom: recursive
    // descent must neither reject nor mangle it.
    let depth = 120;
    let mut text = String::new();
    for _ in 0..depth {
        text.push('[');
    }
    text.push_str("42");
    for _ in 0..depth {
        text.push(']');
    }
    let mut v = &json::parse(&text).unwrap();
    for _ in 0..depth {
        let arr = v.as_array().expect("lost a nesting level");
        assert_eq!(arr.len(), 1);
        v = &arr[0];
    }
    assert_eq!(v.as_u64(), Some(42));
    // Deeply nested objects too.
    let mut text = String::new();
    for _ in 0..60 {
        text.push_str("{\"k\": ");
    }
    text.push_str("true");
    for _ in 0..60 {
        text.push('}');
    }
    let mut v = &json::parse(&text).unwrap();
    for _ in 0..60 {
        v = v.get("k").expect("lost an object level");
    }
    assert_eq!(v.as_bool(), Some(true));
}

#[test]
fn json_rejects_nan_and_infinity_everywhere() {
    for s in [
        "NaN",
        "nan",
        "Infinity",
        "-Infinity",
        "inf",
        "-inf",
        "1e999",          // overflows f64 to +inf — must be rejected, not stored
        "-1e999",
        "[1, NaN]",
        "{\"x\": Infinity}",
    ] {
        assert!(json::parse(s).is_err(), "{s:?} should not parse");
    }
    // The writer side: non-finite floats never reach the document (the
    // report writer emits null instead), so a round-trip stays valid.
    assert_eq!(json::parse("1e308").unwrap().as_f64(), Some(1e308));
}

/// The full pipeline in miniature on the real simulator: a tiny fuzz
/// batch over the production oracle completes clean on a correct tree
/// (the CI smoke step runs the same thing with more cases).
#[test]
fn small_real_fuzz_batch_runs_clean() {
    let base = Config::default();
    let opts = FuzzOpts { cases: 4, seed: 1, parallelism: 2, max_shrink_iters: 120 };
    let rep = houtu::scenario::run_fuzz(&base, &FuzzSpace::default(), &opts);
    assert_eq!(rep.cases, 4);
    assert_eq!(rep.case_digests.len(), 4);
    assert!(
        rep.failures.is_empty(),
        "fuzzer found violations on a correct tree:\n{}",
        rep.render()
    );
    // Digests are replay-stable.
    let again = houtu::scenario::run_fuzz(&base, &FuzzSpace::default(), &opts);
    assert_eq!(rep.case_digests, again.case_digests);
}

#[test]
fn render_mentions_repro_for_failures() {
    let rep = known_bad_report();
    let rendered = rep.render();
    assert!(rendered.contains("failing"), "{rendered}");
    assert!(rendered.contains("repro (campaign --spec)"), "{rendered}");
    assert!(rendered.contains("[scenario."), "{rendered}");
}
