//! Tests for the experiment harness, CLI parsing, CSV export and the
//! pure scheduling helpers in the lifecycle layer.

use houtu::cli;
use houtu::config::{Config, Deployment};
use houtu::deploy::lifecycle::proportional_targets;
use houtu::ids::DcId;

#[test]
fn cli_parses_flags_and_overrides() {
    let args: Vec<String> = [
        "fig8", "--set", "scheduler.tau=0.25", "--set", "workload.num_jobs=3",
        "--deployment", "cent-dyna", "--workload", "pagerank", "--size", "large",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let cli = cli::parse(&args);
    assert_eq!(cli.command, "fig8");
    assert_eq!(cli.cfg.scheduler.tau, 0.25);
    assert_eq!(cli.cfg.workload.num_jobs, 3);
    assert_eq!(cli.deployment, Deployment::CentDyna);
}

#[test]
fn proportional_targets_sum_and_proportionality() {
    // 60/30/10 weights over 10 tasks -> 6/3/1.
    let t = proportional_targets(&[60, 30, 10], 10, DcId(0));
    assert_eq!(t.len(), 10);
    let count = |d: usize| t.iter().filter(|x| x.0 == d).count();
    assert_eq!(count(0), 6);
    assert_eq!(count(1), 3);
    assert_eq!(count(2), 1);
}

#[test]
fn proportional_targets_zero_weights_fall_back_home() {
    let t = proportional_targets(&[0, 0, 0], 4, DcId(2));
    assert!(t.iter().all(|&d| d == DcId(2)));
    assert!(proportional_targets(&[1, 2], 0, DcId(0)).is_empty());
}

#[test]
fn proportional_targets_property_exact_total() {
    use houtu::testkit::{forall, Gen};
    use houtu::util::Pcg;
    struct CaseGen;
    impl Gen<(Vec<u64>, usize)> for CaseGen {
        fn generate(&self, rng: &mut Pcg) -> (Vec<u64>, usize) {
            let n = 1 + rng.index(6);
            let weights = (0..n).map(|_| rng.below(1000)).collect();
            (weights, rng.index(50))
        }
    }
    forall(0xA110C, &CaseGen, |(weights, n): &(Vec<u64>, usize)| {
        let t = proportional_targets(weights, *n, DcId(0));
        if t.len() != *n {
            return Err(format!("len {} != {n}", t.len()));
        }
        // Any DC with zero weight must get zero tasks (unless all zero).
        if weights.iter().sum::<u64>() > 0 {
            for (d, &w) in weights.iter().enumerate() {
                let c = t.iter().filter(|x| x.0 == d).count();
                if w == 0 && c > 0 {
                    return Err(format!("dc{d} weight 0 got {c} tasks"));
                }
                // Largest-remainder: within 1 of the exact share.
                let exact = w as f64 / weights.iter().sum::<u64>() as f64 * *n as f64;
                if (c as f64 - exact).abs() > 1.0 + 1e-9 {
                    return Err(format!("dc{d}: {c} vs exact {exact:.2}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn csv_export_writes_well_formed_files() {
    let mut cfg = Config::default();
    cfg.workload.num_jobs = 4;
    let dir = std::env::temp_dir().join(format!("houtu_csv_{}", std::process::id()));
    let files = houtu::exp::export_csv(&cfg, &dir).unwrap();
    assert_eq!(files.len(), 4);
    for f in &files {
        let text = std::fs::read_to_string(dir.join(f)).unwrap();
        let mut lines = text.lines();
        let header = lines.next().unwrap();
        assert!(header.contains(','), "{f}: no header");
        let cols = header.split(',').count();
        let mut rows = 0;
        for l in lines {
            assert_eq!(l.split(',').count(), cols, "{f}: ragged row {l:?}");
            rows += 1;
        }
        assert!(rows > 0, "{f}: empty");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn fig2_report_contains_all_regions() {
    let cfg = Config::default();
    let r = houtu::exp::fig2_wan(&cfg);
    for region in &cfg.topology.regions {
        assert!(r.contains(region.as_str()), "missing {region}");
    }
}

#[test]
fn random_single_jobs_complete_on_random_deployments() {
    // Mini-fuzz over (kind, size, deployment, home): every combination
    // must complete and return all containers to the pool.
    use houtu::dag::{SizeClass, WorkloadKind};
    use houtu::deploy::{run_single_job, SingleJobPlan};
    use houtu::util::Pcg;
    let mut rng = Pcg::seeded(0xF022);
    let cfg = Config::default();
    for _ in 0..10 {
        let kind = WorkloadKind::ALL[rng.index(4)];
        let size = [SizeClass::Small, SizeClass::Medium][rng.index(2)];
        let mode = Deployment::ALL[rng.index(4)];
        let home = DcId(rng.index(4));
        let w = run_single_job(
            &cfg,
            mode,
            SingleJobPlan { kind, size, home, inject_at: None, kill_jm_at: None },
        );
        assert_eq!(w.metrics.completed_jobs(), 1, "{kind:?} {size:?} {mode:?} {home}");
        for d in 0..4 {
            assert_eq!(
                w.cluster.free_pool(DcId(d)).len(),
                w.cluster.dc_capacity(DcId(d)),
                "pool leak: {kind:?} {size:?} {mode:?}"
            );
        }
    }
}
