//! Theorem 1 (O(1)-competitive makespan for Af + Parades under fair
//! per-DC schedulers) — empirical check across seeds and topologies.

use houtu::config::{Config, Deployment};
use houtu::exp::theorem1_bound;

#[test]
fn competitive_ratio_is_small_constant_across_seeds() {
    let mut cfg = Config::default();
    cfg.workload.num_jobs = 8;
    for seed in [1, 2, 3] {
        cfg.seed = seed;
        let (_, ratio) = theorem1_bound(&cfg);
        assert!(ratio < 10.0, "seed {seed}: ratio {ratio:.2}");
        assert!(ratio >= 1.0, "seed {seed}: makespan below lower bound?!");
    }
}

#[test]
fn ratio_stays_bounded_when_cluster_shrinks() {
    // Half the containers: more contention, the bound's T1/|P| term grows
    // proportionally, so the *ratio* must stay in the same constant range.
    let mut cfg = Config::default();
    cfg.workload.num_jobs = 8;
    cfg.topology.containers_per_worker = 2;
    let (_, ratio) = theorem1_bound(&cfg);
    assert!(ratio < 10.0, "ratio {ratio:.2}");
}

#[test]
fn houtu_makespan_tracks_added_work() {
    // Doubling the job count should not blow the per-job efficiency: the
    // makespan grows sublinearly x2 (arrival spread dominates).
    let mut cfg = Config::default();
    cfg.workload.num_jobs = 6;
    let w6 = houtu::deploy::run_trace_experiment(&cfg, Deployment::Houtu);
    cfg.workload.num_jobs = 12;
    let w12 = houtu::deploy::run_trace_experiment(&cfg, Deployment::Houtu);
    assert!(
        w12.metrics.makespan() < w6.metrics.makespan() * 3.0,
        "6 jobs: {:.0}s, 12 jobs: {:.0}s",
        w6.metrics.makespan(),
        w12.metrics.makespan()
    );
}
