//! Planet-scale world wall: generated topologies + two-tier fidelity.
//!
//! Properties pinned here (see `docs/SCALE.md` for the model):
//!
//! 1. **Purity.** A generated topology is a pure function of
//!    `(dcs, nodes_per_dc, seed)` — regenerating any spec is
//!    bit-identical, across the whole random scale lattice.
//! 2. **Matrix sanity.** Every WAN bandwidth matrix is symmetric, finite
//!    and positive, with the intra-DC (LAN) diagonal strictly dominating
//!    every cross-DC entry.
//! 3. **Prefix stability.** The leading `k×k` block of a grown world
//!    equals the whole `k`-DC world — the property the two-tier
//!    background-invariance wall in `rust/tests/part_world.rs` rests on.
//! 4. **Shrinking.** A failing scale draw walks down the
//!    `(dcs, nodes_per_dc)` lattice to a local minimum, so a red
//!    property prints a small world, not a 256-DC monster.
//! 5. **Engine smoke.** A 16-DC generated world with a 4-DC exact tier
//!    runs a campaign cell thread-count invariantly in CI; the 256-DC
//!    soak of the same pin is `#[ignore]`d for on-demand runs.
//! 6. **Validation.** Chaos targets and tier boundaries outside a
//!    generated world are clear errors, never panics.

use houtu::config::{Config, Deployment};
use houtu::deploy::run_cell_on_parts;
use houtu::ids::DcId;
use houtu::prop_assert;
use houtu::scenario::{ChaosEvent, ScenarioSpec, ScenarioWorkload};
use houtu::testkit::{forall_cases, shrink_failure, Gen};
use houtu::topo::{self, TopoSpec, LAN_BW};
use houtu::util::Pcg;

/// Generator over the topology scale lattice: 2–64 DCs × 1–8 nodes,
/// seeds 1–1000. Shrinking halves each coordinate toward the
/// `(2 DCs, 1 node)` corner and collapses the seed to 1, so every
/// candidate is strictly simpler and the greedy loop terminates at a
/// lattice-local minimum.
struct ScaleGen;

impl Gen<TopoSpec> for ScaleGen {
    fn generate(&self, rng: &mut Pcg) -> TopoSpec {
        TopoSpec {
            dcs: 2 + rng.index(63),
            nodes_per_dc: 1 + rng.index(8),
            seed: 1 + rng.below(1000),
        }
    }

    fn shrink(&self, v: &TopoSpec) -> Vec<TopoSpec> {
        let mut out = Vec::new();
        if v.dcs > 2 {
            out.push(TopoSpec { dcs: (v.dcs / 2).max(2), ..*v });
        }
        if v.nodes_per_dc > 1 {
            out.push(TopoSpec { nodes_per_dc: (v.nodes_per_dc / 2).max(1), ..*v });
        }
        if v.seed > 1 {
            out.push(TopoSpec { seed: 1, ..*v });
        }
        out
    }
}

#[test]
fn topologies_are_a_pure_function_of_the_spec_across_the_scale_lattice() {
    forall_cases(31, 48, &ScaleGen, |ts: &TopoSpec| {
        let a = topo::generate(*ts);
        let b = topo::generate(*ts);
        prop_assert!(a == b, "{ts:?}: regeneration is not bit-identical");
        prop_assert!(a.regions.len() == ts.dcs, "{ts:?}: {} regions", a.regions.len());
        prop_assert!(a.groups.len() == ts.dcs, "{ts:?}: {} groups", a.groups.len());
        prop_assert!(a.bandwidth.len() == ts.dcs, "{ts:?}: {} matrix rows", a.bandwidth.len());
        prop_assert!(
            a.groups.iter().all(|&g| g < topo::CORRELATION_GROUPS),
            "{ts:?}: group index out of range"
        );
        Ok(())
    });
}

#[test]
fn wan_matrices_are_symmetric_finite_positive_and_lan_dominates() {
    forall_cases(32, 32, &ScaleGen, |ts: &TopoSpec| {
        let g = topo::generate(*ts);
        for i in 0..ts.dcs {
            prop_assert!(g.bandwidth[i].len() == ts.dcs, "{ts:?}: row {i} not square");
            prop_assert!(g.bandwidth[i][i] == LAN_BW, "{ts:?}: diagonal [{i}] != LAN");
            for j in 0..ts.dcs {
                let (m, s) = g.bandwidth[i][j];
                prop_assert!(m.is_finite() && m > 0.0, "{ts:?}: mean [{i}][{j}] = {m}");
                prop_assert!(s.is_finite() && s > 0.0, "{ts:?}: std [{i}][{j}] = {s}");
                prop_assert!(
                    g.bandwidth[i][j] == g.bandwidth[j][i],
                    "{ts:?}: asymmetry at [{i}][{j}]"
                );
                if i != j {
                    prop_assert!(
                        m < LAN_BW.0,
                        "{ts:?}: cross-DC [{i}][{j}] {m} beats the intra-DC LAN"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn leading_blocks_are_prefix_stable_across_the_scale_lattice() {
    forall_cases(33, 32, &ScaleGen, |ts: &TopoSpec| {
        let k = (ts.dcs / 2).max(1);
        let small = topo::generate(TopoSpec { dcs: k, ..*ts });
        let big = topo::generate(*ts);
        prop_assert!(big.regions[..k] == small.regions[..], "{ts:?}: region prefix drifted");
        prop_assert!(big.groups[..k] == small.groups[..], "{ts:?}: group prefix drifted");
        for i in 0..k {
            prop_assert!(
                big.bandwidth[i][..k] == small.bandwidth[i][..],
                "{ts:?}: bandwidth row {i} prefix drifted"
            );
        }
        Ok(())
    });
}

/// The shrinker walks a failing draw down the lattice: with a synthetic
/// property that fails exactly when `dcs × nodes_per_dc ≥ 64`, the
/// greedy loop must land on a *local minimum* — still failing, but with
/// both halvings passing — and collapse the seed. For the canonical
/// start the minimum is exactly `(8 DCs, 8 nodes, seed 1)`.
#[test]
fn failing_scales_shrink_to_a_lattice_local_minimum() {
    let fails = |ts: &TopoSpec| ts.dcs * ts.nodes_per_dc >= 64;
    let prop = |ts: &TopoSpec| -> Result<(), String> {
        if fails(ts) {
            Err(format!("{}x{} too big", ts.dcs, ts.nodes_per_dc))
        } else {
            Ok(())
        }
    };
    let start = TopoSpec { dcs: 64, nodes_per_dc: 8, seed: 777 };
    let (best, _, iters) = shrink_failure(&ScaleGen, start, "seed failure".into(), 2000, prop);
    assert!(fails(&best), "shrink left the failing region: {best:?}");
    assert_eq!(best, TopoSpec { dcs: 8, nodes_per_dc: 8, seed: 1 }, "after {iters} probes");
    // Local minimality: every lattice shrink of the minimum passes.
    for cand in ScaleGen.shrink(&best) {
        assert!(!fails(&cand), "shrink stopped early: {cand:?} still fails");
    }
    // And the shrinker is measure-decreasing: candidates of any point
    // are strictly simpler, so the greedy loop always terminates.
    let measure =
        |t: &TopoSpec| (t.dcs * 10 + t.nodes_per_dc) as u64 * 1_000_000 + t.seed.min(999_999);
    forall_cases(34, 32, &ScaleGen, |ts: &TopoSpec| {
        for cand in ScaleGen.shrink(ts) {
            prop_assert!(
                measure(&cand) < measure(ts),
                "{cand:?} not strictly simpler than {ts:?}"
            );
        }
        Ok(())
    });
}

fn planet_spec(total: usize, exact: usize, jobs: usize, events: Vec<ChaosEvent>) -> ScenarioSpec {
    ScenarioSpec {
        name: format!("planet-{total}dc"),
        deployment: Deployment::Houtu,
        regions: 0,
        workload: ScenarioWorkload::Trace { num_jobs: jobs },
        events,
        overrides: vec![
            format!("topology.generated=generated:{total},4,7"),
            format!("topology.exact_dcs={exact}"),
        ],
    }
}

fn pin_cell(spec: &ScenarioSpec, seed: u64, threads: &[usize]) -> houtu::deploy::PartCell {
    let base = Config::default();
    let serial = run_cell_on_parts(&base, spec, seed, 1)
        .unwrap_or_else(|e| panic!("{}/seed{seed}: {e}", spec.name));
    assert!(serial.events > 0, "{}/seed{seed}: empty run", spec.name);
    assert!(serial.jobs_done > 0, "{}/seed{seed}: no job finished", spec.name);
    for &t in threads {
        let run = run_cell_on_parts(&base, spec, seed, t)
            .unwrap_or_else(|e| panic!("{}/seed{seed}/t{t}: {e}", spec.name));
        assert_eq!(
            format!("{:016x}", serial.digest),
            format!("{:016x}", run.digest),
            "{}/seed{seed}: digest diverged at {t} threads",
            spec.name
        );
        assert_eq!(
            (serial.events, serial.tasks_run, serial.jobs_done),
            (run.events, run.tasks_run, run.jobs_done),
            "{}/seed{seed}: counters diverged at {t} threads",
            spec.name
        );
    }
    serial
}

/// The fast CI cell: a 16-DC generated world with a 4-DC exact tier
/// runs a 3-job trace (plus an in-tier spot storm) bit-identically at
/// 1, 2 and 4 threads, replays in lockstep, and the seed moves the
/// stream.
#[test]
fn generated_16dc_world_is_thread_count_invariant() {
    let spec = planet_spec(
        16,
        4,
        3,
        vec![ChaosEvent::SpotStorm {
            at_secs: 20.0,
            dc: DcId(1),
            dur_secs: 90.0,
            sigma_factor: 2.5,
        }],
    );
    let a = pin_cell(&spec, 42, &[2, 4]);
    let again = run_cell_on_parts(&Config::default(), &spec, 42, 2).unwrap();
    assert_eq!((a.digest, a.events, a.tasks_run), (again.digest, again.events, again.tasks_run));
    let b = pin_cell(&spec, 7, &[2]);
    assert_ne!(a.digest, b.digest, "the seed must move the stream");
}

/// The 256-DC soak: the same pin at planetary scale, with a chaos event
/// promoting a deep background DC mid-run. Run on demand with
/// `cargo test --test planet -- --ignored`.
#[test]
#[ignore = "256-DC soak; run on demand"]
fn generated_256dc_world_is_thread_count_invariant() {
    let spec = planet_spec(
        256,
        4,
        4,
        vec![ChaosEvent::KillDc { at_secs: 30.0, dc: DcId(200) }],
    );
    pin_cell(&spec, 42, &[4]);
}

/// Chaos targets and tier boundaries validate against the *generated*
/// DC count with clear errors, not panics.
#[test]
fn out_of_range_targets_against_generated_worlds_are_clear_errors() {
    let base = Config::default();
    let mut bad = planet_spec(64, 4, 1, vec![ChaosEvent::KillDc { at_secs: 10.0, dc: DcId(70) }]);
    let e = run_cell_on_parts(&base, &bad, 42, 1).expect_err("dc70 of 64").to_string();
    assert!(e.contains("outside the 64-region topology"), "{e}");
    bad.events = vec![ChaosEvent::SpotStorm {
        at_secs: 10.0,
        dc: DcId(100),
        dur_secs: 60.0,
        sigma_factor: 2.0,
    }];
    let e = run_cell_on_parts(&base, &bad, 42, 1).expect_err("dc100 of 64").to_string();
    assert!(e.contains("outside the 64-region topology"), "{e}");
    // A malformed token fails at parse with the token named.
    bad.events = vec![];
    bad.overrides = vec!["topology.generated=generated:sixty-four,4,7".into()];
    let e = run_cell_on_parts(&base, &bad, 42, 1).expect_err("bad token").to_string();
    assert!(e.contains("topology spec"), "{e}");
    // An exact-tier boundary past the world's edge is rejected too.
    bad.overrides = vec![
        "topology.generated=generated:16,4,7".into(),
        "topology.exact_dcs=99".into(),
    ];
    let e = run_cell_on_parts(&base, &bad, 42, 1).expect_err("tier > world").to_string();
    assert!(e.contains("exceeds the topology's 16 DCs"), "{e}");
}
