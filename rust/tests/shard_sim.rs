//! Integration tests for the conservative-parallel sharded DES engine
//! (`houtu::sim::ShardedSim`) through the public API only.
//!
//! The contract under test: the merged execution — per-part event
//! streams, trace digest and state — is a pure function of the seeded
//! workload, invariant to the shard count, to serial vs threaded
//! execution, and across repeated parallel runs. The WAN bridge
//! (`houtu::net::wan_lookahead`) must hand the engine floors that are
//! genuine lower bounds on the topology's delays.

use houtu::config::Config;
use houtu::net::wan_lookahead;
use houtu::sim::{Lookahead, ShardCtx, ShardEvent, ShardedSim};

/// splitmix64 finalizer: hash-derived routing keeps the workload
/// deterministic without threading an RNG through the handlers.
fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// A token chain: each hop folds into the owning part's accumulator and
/// forwards itself to a hash-chosen part with hash-chosen extra delay.
struct Hop {
    token: u64,
    left: u32,
}

impl ShardEvent<u64> for Hop {
    fn apply(self, ctx: &mut ShardCtx<'_, u64, Hop>) {
        let part = ctx.part();
        let nparts = ctx.nparts();
        let mut x = mix(self.token ^ (part as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15));
        *ctx.state = (*ctx.state).wrapping_add(x);
        if self.left > 0 {
            let to = (x % nparts as u64) as usize;
            x = mix(x);
            ctx.send(to, x & 31, Hop { token: x, left: self.left - 1 });
        }
    }

    fn kind(&self) -> &'static str {
        "hop"
    }
}

const PARTS: usize = 4;
const CHAINS: usize = 12;
const HOPS: u32 = 60;

/// Run the chain workload and return (digest, events, state checksum).
fn run_hops(shards: usize, parallel: bool) -> (u64, u64, u64) {
    let la = Lookahead::uniform(PARTS, 5);
    let mut sim = ShardedSim::new(vec![0u64; PARTS], la, shards);
    for i in 0..CHAINS {
        sim.seed(i % PARTS, 1 + i as u64, Hop { token: mix(0xABCD + i as u64), left: HOPS });
    }
    if parallel {
        sim.run();
    } else {
        sim.run_serial();
    }
    let checksum = (0..PARTS).fold(0u64, |a, p| a.wrapping_add(*sim.part_state(p)));
    (sim.digest(), sim.events_processed(), checksum)
}

#[test]
fn outcome_is_invariant_across_shard_counts_and_execution_modes() {
    let (g_dig, g_ev, g_sum) = run_hops(1, false);
    assert_eq!(g_ev, (CHAINS as u64) * (HOPS as u64 + 1), "every hop executes exactly once");
    assert_ne!(g_dig, 0, "degenerate digest");
    for shards in [1usize, 2, 3, 4, 8] {
        for parallel in [false, true] {
            let (d, e, s) = run_hops(shards, parallel);
            assert_eq!(d, g_dig, "digest drifted at shards={shards} parallel={parallel}");
            assert_eq!(e, g_ev, "events drifted at shards={shards} parallel={parallel}");
            assert_eq!(s, g_sum, "state drifted at shards={shards} parallel={parallel}");
        }
    }
}

#[test]
fn parallel_runs_are_bit_reproducible() {
    let a = run_hops(PARTS, true);
    let b = run_hops(PARTS, true);
    assert_eq!(a, b, "two threaded runs of the same workload must agree exactly");
}

#[test]
fn shard_count_clamps_to_the_part_count() {
    let build = |shards| {
        ShardedSim::<u64, Hop>::new(vec![0u64; 3], Lookahead::uniform(3, 2), shards)
    };
    let wide = build(16);
    assert_eq!(wide.num_parts(), 3);
    assert!(wide.num_shards() <= 3, "no more shards than parts");
    let zero = build(0);
    assert_eq!(zero.num_shards(), 1, "zero means sequential, not empty");
}

#[test]
fn wan_lookahead_floors_drive_the_engine() {
    let cfg = Config::default();
    let la = wan_lookahead(&cfg.wan, PARTS);
    assert_eq!(la.parts(), PARTS);
    let cross = (cfg.wan.rtt_ms / 2.0).floor().max(1.0) as u64;
    for a in 0..PARTS {
        for b in 0..PARTS {
            let floor = la.floor(a, b);
            assert!(floor >= 1, "floors must guarantee progress");
            assert_eq!(floor, if a == b { 1 } else { cross }, "({a},{b})");
        }
    }
    let mut sim = ShardedSim::new(vec![0u64; PARTS], la, PARTS);
    for i in 0..8 {
        sim.seed(i % PARTS, 1, Hop { token: mix(i as u64), left: 30 });
    }
    sim.run();
    assert_eq!(sim.events_processed(), 8 * 31, "WAN floors must not drop or stall events");
    assert!(sim.now() > 0);
    assert!(sim.shard_clock(0).steps() > 0, "shard 0 executed work under its clock");
}
