//! Scenario-matrix chaos engine: campaign parsing, engine-vs-hand-coded
//! parity, invariant checkers, deterministic replay, and end-to-end
//! campaign runs.

use houtu::config::{Config, Deployment};
use houtu::dag::{SizeClass, WorkloadKind};
use houtu::deploy::{run_single_job, SingleJobPlan};
use houtu::ids::{DcId, JobId};
use houtu::scenario::{
    check_world, presets, run_campaign, run_fuzz_with, run_one, run_scenario, smoke_campaign,
    standard_campaign, CampaignSpec, CellOutcome, FuzzOpts, FuzzSpace, ScenarioSpec,
    ScenarioWorkload,
};

fn stolen_in(w: &houtu::deploy::World) -> u64 {
    w.jobs
        .values()
        .flat_map(|rt| rt.jms.values())
        .map(|jm| jm.stats.tasks_stolen_in)
        .sum()
}

#[test]
fn shipped_campaign_toml_defines_the_full_matrix() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/configs/campaign.toml");
    let spec = CampaignSpec::from_file(path).unwrap();
    assert!(spec.scenarios.len() >= 4, "{} scenarios", spec.scenarios.len());
    assert!(spec.seeds.len() >= 3, "{} seeds", spec.seeds.len());
    assert!(spec.expand().len() >= 12, "{} runs", spec.expand().len());
    // The built-in fallback stays in sync with the shipped file — full
    // structural equality, so edits to events/overrides can't drift.
    let builtin = standard_campaign();
    assert_eq!(builtin.name, spec.name);
    assert_eq!(builtin.seeds, spec.seeds);
    assert_eq!(builtin.scenarios, spec.scenarios);
    // Every scenario builds a valid config at every seed.
    for (sc, seed) in spec.expand() {
        sc.build_config(&Config::default(), seed).unwrap();
    }
}

#[test]
fn cli_parses_campaign_flags() {
    let args: Vec<String> =
        ["campaign", "--spec", "configs/campaign.toml"].iter().map(|s| s.to_string()).collect();
    let cli = houtu::cli::parse(&args);
    assert_eq!(cli.command, "campaign");
    assert_eq!(cli.spec.as_deref(), Some("configs/campaign.toml"));
    assert!(!cli.smoke);
    let args: Vec<String> = ["campaign", "--smoke"].iter().map(|s| s.to_string()).collect();
    assert!(houtu::cli::parse(&args).smoke);
    let args: Vec<String> = ["campaign", "--smoke", "--report", "/tmp/r.json"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    assert_eq!(houtu::cli::parse(&args).report.as_deref(), Some("/tmp/r.json"));
}

/// End-to-end report export: run the smoke campaign, write JSON and CSV,
/// and verify both round-trip (the same path `houtu campaign --report`
/// and ci.sh exercise).
#[test]
fn campaign_report_exports_and_round_trips() {
    let report = run_campaign(&Config::default(), &smoke_campaign());
    let dir = std::env::temp_dir();
    let json_path = dir.join("houtu_test_report.json");
    let csv_path = dir.join("houtu_test_report.csv");
    let json_path = json_path.to_str().unwrap();
    let csv_path = csv_path.to_str().unwrap();
    assert_eq!(houtu::scenario::write_and_verify(&report, json_path).unwrap(), "json");
    assert_eq!(houtu::scenario::write_and_verify(&report, csv_path).unwrap(), "csv");
    // The JSON really parses with the in-repo parser and carries the runs.
    let text = std::fs::read_to_string(json_path).unwrap();
    let doc = houtu::util::json::parse(&text).unwrap();
    let runs = doc.get("runs").and_then(houtu::util::json::Json::as_array).unwrap();
    assert_eq!(runs.len(), report.runs.len());
    let _ = std::fs::remove_file(json_path);
    let _ = std::fs::remove_file(csv_path);
}

/// Parity with the hand-coded Fig-9 injection experiment: the engine
/// preset must reproduce `run_single_job` exactly (same DES trajectory),
/// and the original assertions must keep holding.
#[test]
fn fig9_injection_parity_with_run_single_job() {
    let cfg = Config::default();
    let direct = run_single_job(
        &cfg,
        Deployment::Houtu,
        SingleJobPlan {
            kind: WorkloadKind::PageRank,
            size: SizeClass::Large,
            home: DcId(1),
            inject_at: Some((100.0, vec![DcId(0), DcId(2), DcId(3)])),
            kill_jm_at: None,
        },
    );
    let engine = run_scenario(&cfg, &presets::fig9_inject_steal(), cfg.seed).unwrap().world;
    // Unchanged assertions from the hand-coded experiment...
    assert_eq!(engine.metrics.completed_jobs(), 1);
    assert!(stolen_in(&engine) > 0, "no tasks stolen despite resource-tense DCs");
    // ...and bit-exact parity with the direct run.
    let jrt = |w: &houtu::deploy::World| w.metrics.jobs[&JobId(0)].jrt().unwrap();
    assert_eq!(jrt(&direct).to_bits(), jrt(&engine).to_bits(), "JRT diverged");
    assert_eq!(stolen_in(&direct), stolen_in(&engine));
    assert_eq!(
        direct.wan.stats.cross_dc_total_bytes(),
        engine.wan.stats.cross_dc_total_bytes()
    );
    assert_eq!(
        direct.metrics.task_launches[&JobId(0)],
        engine.metrics.task_launches[&JobId(0)]
    );
}

/// Parity with the hand-coded Fig-11 pJM-kill experiment.
#[test]
fn fig11_pjm_kill_parity_with_run_single_job() {
    let cfg = Config::default();
    let direct = run_single_job(
        &cfg,
        Deployment::Houtu,
        SingleJobPlan {
            kind: WorkloadKind::WordCount,
            size: SizeClass::Large,
            home: DcId(0),
            inject_at: None,
            kill_jm_at: Some((70.0, DcId(0))),
        },
    );
    let engine =
        run_scenario(&cfg, &presets::fig11_kill(DcId(0), Deployment::Houtu), cfg.seed)
            .unwrap()
            .world;
    // Unchanged assertions from the hand-coded experiment...
    assert_eq!(engine.metrics.completed_jobs(), 1);
    assert!(!engine.metrics.election_delays_secs.is_empty(), "no election recorded");
    assert_ne!(engine.jobs[&JobId(0)].primary, DcId(0), "primary stayed on the killed DC");
    // ...and parity with the direct run.
    let jrt = |w: &houtu::deploy::World| w.metrics.jobs[&JobId(0)].jrt().unwrap();
    assert_eq!(jrt(&direct).to_bits(), jrt(&engine).to_bits());
    assert_eq!(
        direct.metrics.recovery_intervals_secs.len(),
        engine.metrics.recovery_intervals_secs.len()
    );
    assert_eq!(
        direct.metrics.election_delays_secs.len(),
        engine.metrics.election_delays_secs.len()
    );
}

/// The §6.4 revocation-chaos experiment ported onto the engine, with the
/// original assertions unchanged.
#[test]
fn revocation_chaos_survives_through_engine() {
    let mut base = Config::default();
    base.workload.num_jobs = 8; // overridden by the preset's Trace { 6 }
    let run = run_scenario(&base, &presets::revocation_chaos(6), 42).unwrap();
    let w = &run.world;
    assert_eq!(w.metrics.completed_jobs(), 6, "jobs lost to revocations");
    let recoveries = w.metrics.recovery_intervals_secs.len();
    let restarts: u32 = w.metrics.jobs.values().map(|j| j.restarts).sum();
    assert!(
        recoveries > 0 || restarts == 0,
        "expected JM recoveries under chaos (got {recoveries} recoveries, {restarts} restarts)"
    );
    let violations = check_world(w);
    assert!(violations.is_empty(), "{violations:?}");
}

#[test]
fn invariants_pass_on_clean_and_chaotic_presets() {
    let cfg = Config::default();
    for spec in [
        presets::fig9_normal(),
        presets::fig9_inject_steal(),
        presets::fig11_kill(DcId(2), Deployment::Houtu),
        presets::fig11_kill(DcId(0), Deployment::CentDyna),
    ] {
        let run = run_scenario(&cfg, &spec, cfg.seed).unwrap();
        let violations = check_world(&run.world);
        assert!(violations.is_empty(), "{}: {violations:?}", spec.name);
        assert_eq!(run.world.metrics.completed_jobs(), 1, "{}", spec.name);
    }
}

#[test]
fn invariant_checker_detects_tampering() {
    let cfg = Config::default();
    let mut run = run_scenario(&cfg, &presets::fig9_normal(), cfg.seed).unwrap();
    assert!(check_world(&run.world).is_empty());
    // Forge a lost completion: the checker must notice.
    run.world.metrics.jobs.get_mut(&JobId(0)).unwrap().completed_secs = None;
    let violations = check_world(&run.world);
    assert!(
        violations.iter().any(|v| v.check == "job-terminates"),
        "{violations:?}"
    );
    // Forge a duplicated partition entry: exactly-once must notice.
    let mut run = run_scenario(&cfg, &presets::fig9_normal(), cfg.seed).unwrap();
    let dup = run.world.jobs.get_mut(&JobId(0)).unwrap();
    let first = dup.info.partition_list[0].clone();
    dup.info.partition_list.push(first);
    let violations = check_world(&run.world);
    assert!(violations.iter().any(|v| v.check == "exactly-once"), "{violations:?}");
}

/// Deterministic replay: same (scenario, seed) ⇒ byte-identical digests
/// (event count included); different seeds ⇒ different digests.
#[test]
fn campaign_digests_replay_deterministically() {
    let base = Config::default();
    let spec = ScenarioSpec {
        name: "replay".into(),
        deployment: Deployment::Houtu,
        regions: 0,
        workload: ScenarioWorkload::Trace { num_jobs: 8 },
        events: vec![],
        overrides: vec![],
    };
    let a = run_one(&base, &spec, 42);
    let b = run_one(&base, &spec, 42);
    assert!(a.passed(), "{:?}", a.violations);
    assert_eq!(a.digest, b.digest, "same (spec, seed) must replay identically");
    assert_eq!(a.events_processed, b.events_processed);
    assert_eq!(a.avg_jrt_secs.to_bits(), b.avg_jrt_secs.to_bits());
    let c = run_one(&base, &spec, 1234);
    assert!(c.passed(), "{:?}", c.violations);
    assert_ne!(a.digest, c.digest, "different seeds must differ");
}

#[test]
fn smoke_campaign_runs_clean_in_parallel() {
    let report = run_campaign(&Config::default(), &smoke_campaign());
    assert_eq!(report.runs.len(), 4, "2 scenarios × 2 seeds");
    assert!(report.all_pass(), "{}", report.render());
    // Matrix order is stable regardless of worker interleaving.
    let labels: Vec<(String, u64)> =
        report.runs.iter().map(|r| (r.scenario.clone(), r.seed)).collect();
    assert_eq!(
        labels,
        vec![
            ("baseline-wordcount".to_string(), 42),
            ("baseline-wordcount".to_string(), 99),
            ("hogs-pagerank".to_string(), 42),
            ("hogs-pagerank".to_string(), 99),
        ]
    );
    let rendered = report.render();
    assert!(rendered.contains("runs clean"), "{rendered}");
}

/// The shipped campaign's chaotic cells at its non-default seeds: JM
/// kills and the spot storm must recover cleanly wherever the seed lands
/// them in the job's lifetime.
#[test]
fn standard_campaign_risky_cells_run_clean() {
    let base = Config::default();
    let std_campaign = standard_campaign();
    let by_name = |n: &str| -> ScenarioSpec {
        std_campaign.scenarios.iter().find(|s| s.name == n).unwrap().clone()
    };
    for seed in [7u64, 1234] {
        for name in [
            "pjm-kill",
            "spot-chaos",
            "jm-kill-cascade",
            "asym-wan-partition",
            "dc-outage",
            "spot-storm",
            "straggler-storm",
            "bid-insurance-storm",
        ] {
            let rep = run_one(&base, &by_name(name), seed);
            assert!(rep.passed(), "{name}/seed{seed}: {:?}", rep.violations);
            assert_eq!(rep.completed_jobs, rep.total_jobs, "{name}/seed{seed}");
        }
    }
}

#[test]
fn broken_scenario_reports_instead_of_crashing() {
    let base = Config::default();
    let spec = ScenarioSpec {
        name: "bad-override".into(),
        deployment: Deployment::Houtu,
        regions: 0,
        workload: ScenarioWorkload::Trace { num_jobs: 1 },
        events: vec![],
        overrides: vec!["scheduler.delta=7".into()],
    };
    let rep = run_one(&base, &spec, 1);
    assert!(!rep.passed());
    assert!(rep.violations[0].contains("spec:"), "{:?}", rep.violations);
}

/// The topology axis: the same scenario runs on 2 and 8 regions.
#[test]
fn topology_axis_expands_regions() {
    let base = Config::default();
    for regions in [2usize, 8] {
        let spec = ScenarioSpec {
            name: format!("topo-{regions}"),
            deployment: Deployment::Houtu,
            regions,
            workload: ScenarioWorkload::SingleJob {
                kind: WorkloadKind::WordCount,
                size: SizeClass::Small,
                home: DcId(0),
            },
            events: vec![],
            overrides: vec![],
        };
        let run = run_scenario(&base, &spec, 7).unwrap();
        assert_eq!(run.world.cfg.topology.num_dcs(), regions);
        assert_eq!(run.world.metrics.completed_jobs(), 1);
        let violations = check_world(&run.world);
        assert!(violations.is_empty(), "{regions} regions: {violations:?}");
    }
}

/// WAN degradation windows slow a job down and restore cleanly.
#[test]
fn wan_degrade_window_slows_the_job() {
    let base = Config::default();
    let mk = |events| ScenarioSpec {
        name: "wan-brownout".into(),
        deployment: Deployment::Houtu,
        regions: 0,
        workload: ScenarioWorkload::SingleJob {
            kind: WorkloadKind::TpcH,
            size: SizeClass::Medium,
            home: DcId(0),
        },
        events,
        overrides: vec![],
    };
    let calm = run_scenario(&base, &mk(vec![]), 42).unwrap();
    let stormy = run_scenario(
        &base,
        &mk(vec![houtu::scenario::ChaosEvent::WanDegrade {
            from_secs: 5.0,
            until_secs: 400.0,
            factor: 0.05,
        }]),
        42,
    )
    .unwrap();
    assert_eq!(stormy.world.metrics.completed_jobs(), 1);
    assert!(check_world(&stormy.world).is_empty());
    assert!((stormy.world.wan.degrade_factor() - 1.0).abs() < 1e-12, "degradation not restored");
    let jrt = |w: &houtu::deploy::World| w.metrics.jobs[&JobId(0)].jrt().unwrap();
    assert!(
        jrt(&stormy) > jrt(&calm),
        "brownout {:.1}s should exceed calm {:.1}s",
        jrt(&stormy),
        jrt(&calm)
    );
}

/// Golden replay-digest pins for the three new chaos families at fixed
/// seeds: every (cell, seed) replays to a bit-identical digest, different
/// seeds diverge, and the injected chaos is visible in the event stream
/// (a chaos-free twin digests differently).
#[test]
fn new_chaos_family_digests_pin_deterministic_replay() {
    let base = Config::default();
    let campaign = standard_campaign();
    let by_name = |n: &str| -> ScenarioSpec {
        campaign.scenarios.iter().find(|s| s.name == n).unwrap().clone()
    };
    for name in ["dc-outage", "spot-storm", "straggler-storm"] {
        let spec = by_name(name);
        let mut digests = Vec::new();
        for seed in [42u64, 7] {
            let a = run_one(&base, &spec, seed);
            let b = run_one(&base, &spec, seed);
            assert!(a.passed(), "{name}/seed{seed}: {:?}", a.violations);
            assert_eq!(a.digest, b.digest, "{name}/seed{seed}: replay diverged");
            assert_eq!(a.events_processed, b.events_processed, "{name}/seed{seed}");
            digests.push(a.digest);
        }
        assert_ne!(digests[0], digests[1], "{name}: seeds 42 and 7 digested identically");
        let calm = ScenarioSpec { events: vec![], overrides: vec![], ..spec.clone() };
        let c = run_one(&base, &calm, 42);
        assert!(c.passed(), "{name} calm twin: {:?}", c.violations);
        assert_ne!(c.digest, digests[0], "{name}: chaos left no trace in the digest");
    }
}

/// The `kill_dc@` family semantics: the whole region dies at the fig11
/// kill instant, the sJM it hosted recovers, and the run stays clean.
#[test]
fn kill_dc_outage_recovers_and_passes_invariants() {
    let base = Config::default();
    let campaign = standard_campaign();
    let spec =
        campaign.scenarios.iter().find(|s| s.name == "dc-outage").unwrap().clone();
    let run = run_scenario(&base, &spec, 42).unwrap();
    let w = &run.world;
    assert_eq!(w.metrics.completed_jobs(), 1);
    let violations = check_world(w);
    assert!(violations.is_empty(), "{violations:?}");
    assert!(
        !w.metrics.recovery_intervals_secs.is_empty(),
        "whole-DC outage killed the dc2 sJM, but no recovery was recorded"
    );
    // Every dc2 node came back: full capacity restored post-run.
    assert_eq!(
        w.cluster.free_pool(DcId(2)).len(),
        w.cluster.dc_capacity(DcId(2)),
        "dc2 did not re-acquire its instances"
    );
}

/// The straggler sweep axes actually perturb execution: with stragglers
/// on, the same (scenario, seed) runs strictly slower than its calm twin
/// while staying exactly-once clean.
#[test]
fn straggler_sweep_slows_the_job_but_stays_clean() {
    let base = Config::default();
    let campaign = standard_campaign();
    let spec =
        campaign.scenarios.iter().find(|s| s.name == "straggler-storm").unwrap().clone();
    let stormy = run_scenario(&base, &spec, 42).unwrap();
    let calm_spec = ScenarioSpec { overrides: vec![], ..spec };
    let calm = run_scenario(&base, &calm_spec, 42).unwrap();
    for (label, w) in [("straggler", &stormy.world), ("calm", &calm.world)] {
        assert_eq!(w.metrics.completed_jobs(), 1, "{label}");
        let violations = check_world(w);
        assert!(violations.is_empty(), "{label}: {violations:?}");
    }
    let jrt = |w: &houtu::deploy::World| w.metrics.jobs[&JobId(0)].jrt().unwrap();
    assert!(
        jrt(&stormy.world) > jrt(&calm.world),
        "straggler storm {:.1}s should exceed calm {:.1}s",
        jrt(&stormy.world),
        jrt(&calm.world)
    );
}

/// Fuzz results are worker-count invariant: cells are generated from the
/// fuzz seed before execution and shrinking is sequential, so 1 worker
/// and 4 workers produce identical digests and identical minimized
/// failures.
#[test]
fn fuzz_results_are_worker_count_invariant() {
    let base = Config::default();
    let space = FuzzSpace::default();
    // Synthetic oracle keeps this fast while still exercising the whole
    // generate → execute → shrink pipeline; `digest` is derived from the
    // cell so reordering across workers would be visible.
    let oracle = |_b: &Config, s: &ScenarioSpec, seed: u64| {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in format!("{}|{}|{seed}", s.name, s.events.len()).bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        CellOutcome {
            violations: if s.events.len() >= 2 {
                vec!["synthetic: two-event schedules fail".to_string()]
            } else {
                vec![]
            },
            digest: h,
            usd: 0.0,
        }
    };
    let mut total_failures = 0;
    for seed in 9u64..13 {
        let run = |parallelism: usize| {
            let opts = FuzzOpts { cases: 24, seed, parallelism, max_shrink_iters: 2000 };
            run_fuzz_with(&base, &space, &opts, &oracle)
        };
        let solo = run(1);
        let pooled = run(4);
        assert_eq!(solo.cases, pooled.cases);
        assert_eq!(
            solo.case_digests, pooled.case_digests,
            "seed {seed}: digest order depends on workers"
        );
        assert_eq!(solo.failures.len(), pooled.failures.len(), "seed {seed}");
        for (a, b) in solo.failures.iter().zip(&pooled.failures) {
            assert_eq!(a.case_index, b.case_index, "seed {seed}");
            assert_eq!(a.original, b.original, "seed {seed}");
            assert_eq!(a.shrunk, b.shrunk, "seed {seed}: shrinking depends on workers");
            assert_eq!(a.violations, b.violations, "seed {seed}");
        }
        // The synthetic property "≥ 2 events fail" has 2-event minima.
        for f in &solo.failures {
            assert_eq!(f.shrunk.spec.events.len(), 2, "{:?}", f.shrunk.spec.events);
        }
        total_failures += solo.failures.len();
    }
    assert!(total_failures > 0, "96 sampled cells never drew a two-event schedule");
}

#[test]
fn cli_parses_fuzz_flags() {
    let args: Vec<String> = ["fuzz", "--cases", "8", "--seed", "3", "--repro", "/tmp/r.toml"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let cli = houtu::cli::parse(&args);
    assert_eq!(cli.command, "fuzz");
    assert_eq!(cli.cases, 8);
    assert_eq!(cli.fuzz_seed, 3);
    assert_eq!(cli.repro.as_deref(), Some("/tmp/r.toml"));
    assert_eq!(cli.soak_minutes, None);
    let args: Vec<String> =
        ["fuzz", "--soak", "0.5"].iter().map(|s| s.to_string()).collect();
    let cli = houtu::cli::parse(&args);
    assert_eq!(cli.soak_minutes, Some(0.5));
    assert_eq!(cli.cases, 32, "default case count");
    assert_eq!(cli.fuzz_seed, 1, "default fuzz seed");
}

#[test]
fn cli_parses_sharded_threads_and_history_flags() {
    let args: Vec<String> = ["campaign", "--smoke", "--shards", "4", "--threads", "2"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let cli = houtu::cli::parse(&args);
    assert_eq!(cli.shards, Some(4));
    assert_eq!(cli.threads, 2);
    let args: Vec<String> = ["bench", "--smoke", "--history", "/tmp/h.jsonl"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let cli = houtu::cli::parse(&args);
    assert_eq!(cli.history.as_deref(), Some("/tmp/h.jsonl"));
    assert_eq!(cli.shards, None, "default stays on the sequential engine");
    assert_eq!(cli.threads, 0, "default resolves via HOUTU_THREADS, then cores");
}
