"""L2 model tests: training actually learns; artifacts lower to HLO text."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_logreg_train_step_reduces_loss():
    r = np.random.default_rng(0)
    n, d = 256, 16
    true_w = r.normal(size=d)
    x = r.normal(size=(n, d)).astype(np.float32)
    y = (x @ true_w > 0).astype(np.float32)
    w = jnp.zeros(d, jnp.float32)
    losses = []
    for _ in range(30):
        w, loss = model.logreg_train_step(w, jnp.asarray(x), jnp.asarray(y), jnp.float32(0.5))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses[::10]


def test_pagerank_iteration_converges():
    r = np.random.default_rng(1)
    n = 64
    a = (r.random((n, n)) < 0.3).astype(np.float32)
    a[0, :] = 1.0
    m = jnp.asarray(a / a.sum(axis=0, keepdims=True))
    rank = jnp.full((n,), 1.0 / n, jnp.float32)
    resids = []
    for _ in range(25):
        rank, resid = model.pagerank_iteration(m, rank, jnp.float32(0.85))
        resids.append(float(resid))
    assert resids[-1] < 1e-4, resids[::5]
    np.testing.assert_allclose(float(rank.sum()), 1.0, rtol=1e-4)


def test_wordcount_agg_counts_tokens():
    seg = np.array([0, 1, 1, 2, 2, 2])
    onehot = jnp.asarray(np.eye(3, dtype=np.float32)[seg])
    ones = jnp.ones((6, 1), jnp.float32)
    out = model.wordcount_agg(onehot, ones)
    np.testing.assert_allclose(out[:, 0], [1.0, 2.0, 3.0])


def test_artifacts_lower_to_hlo_text():
    for name, lowered in aot.artifacts().items():
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text, name
        assert "HloModule" in text, name
        # The tuple-return convention the rust loader expects.
        assert "ROOT" in text, name
