"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes (including non-multiples of the 128-row tiles)
and value ranges; every Pallas kernel must match its pure-jnp oracle to
float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import logreg, pagerank, ref, segsum

jax.config.update("jax_platform_name", "cpu")

SETTINGS = dict(max_examples=25, deadline=None)


def rng(seed):
    return np.random.default_rng(seed)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 400),
    d=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_logreg_grad_matches_ref(n, d, seed):
    r = rng(seed)
    w = jnp.asarray(r.normal(size=d), jnp.float32)
    x = jnp.asarray(r.normal(size=(n, d)), jnp.float32)
    y = jnp.asarray(r.integers(0, 2, size=n), jnp.float32)
    got = logreg.logreg_grad(w, x, y)
    want = ref.logreg_grad(w, x, y)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


@settings(**SETTINGS)
@given(n=st.integers(2, 300), seed=st.integers(0, 2**31 - 1), damping=st.floats(0.5, 0.95))
def test_pagerank_step_matches_ref(n, seed, damping):
    r = rng(seed)
    # Column-normalized random link matrix (transposed).
    a = (r.random((n, n)) < 0.2).astype(np.float32)
    a[0, :] = 1.0  # no dangling columns
    m = jnp.asarray(a / a.sum(axis=0, keepdims=True))
    rank = jnp.asarray(r.random(n), jnp.float32)
    rank = rank / rank.sum()
    got = pagerank.pagerank_step(m, rank, damping)
    want = ref.pagerank_step(m, rank, damping)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


@settings(**SETTINGS)
@given(
    n=st.integers(1, 400),
    k=st.integers(1, 64),
    v=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_segsum_matches_ref(n, k, v, seed):
    r = rng(seed)
    seg = r.integers(0, k, size=n)
    onehot = jnp.asarray(np.eye(k, dtype=np.float32)[seg])
    values = jnp.asarray(r.normal(size=(n, v)), jnp.float32)
    got = segsum.segsum(onehot, values)
    want = ref.segsum(onehot, values)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_logreg_padding_rows_contribute_zero():
    # n exactly on a tile boundary vs one past it with a zero row.
    r = rng(0)
    d = 8
    w = jnp.asarray(r.normal(size=d), jnp.float32)
    x = jnp.asarray(r.normal(size=(128, d)), jnp.float32)
    y = jnp.asarray(r.integers(0, 2, size=128), jnp.float32)
    g1 = logreg.logreg_grad(w, x, y)
    # 129 rows: grad averages over 129, so compare unnormalized sums.
    x2 = jnp.concatenate([x, jnp.zeros((1, d), jnp.float32)])
    y2 = jnp.concatenate([y, jnp.asarray([0.5], jnp.float32)])
    g2 = logreg.logreg_grad(w, x2, y2)
    np.testing.assert_allclose(g1 * 128, g2 * 129, rtol=2e-5, atol=1e-6)


def test_pagerank_preserves_probability_mass():
    r = rng(1)
    n = 130  # non-multiple of BLOCK
    a = np.ones((n, n), np.float32)
    m = jnp.asarray(a / a.sum(axis=0, keepdims=True))
    rank = jnp.full((n,), 1.0 / n, jnp.float32)
    out = pagerank.pagerank_step(m, rank, 0.85)
    np.testing.assert_allclose(out.sum(), 1.0, rtol=1e-5)


def test_segsum_empty_segment_is_zero():
    onehot = jnp.zeros((4, 3), jnp.float32).at[:, 0].set(1.0)
    values = jnp.ones((4, 2), jnp.float32)
    out = segsum.segsum(onehot, values)
    np.testing.assert_allclose(out[0], [4.0, 4.0])
    np.testing.assert_allclose(out[1:], 0.0)
