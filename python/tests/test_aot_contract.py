"""Cross-language contract: the export shapes in aot.py must match the
constants the rust runtime pads its batches to, and the emitted HLO must
carry the donation/layout properties EXPERIMENTS.md claims."""

import re
from pathlib import Path

from compile import aot

REPO = Path(__file__).resolve().parents[2]


def rust_const(name: str) -> int:
    text = (REPO / "rust/src/runtime/mod.rs").read_text()
    m = re.search(rf"pub const {name}: usize = (\d+);", text)
    assert m, f"{name} not found in rust runtime"
    return int(m.group(1))


def test_shapes_match_rust_runtime():
    assert aot.LOGREG_N == rust_const("LOGREG_N")
    assert aot.LOGREG_D == rust_const("LOGREG_D")
    assert aot.PAGERANK_N == rust_const("PAGERANK_N")
    assert aot.SEG_N == rust_const("SEG_N")
    assert aot.SEG_K == rust_const("SEG_K")
    assert aot.SEG_V == rust_const("SEG_V")


def test_logreg_artifact_donates_weight_buffer():
    text = aot.to_hlo_text(aot.artifacts()["logreg_step"])
    assert "input_output_alias" in text, "weight buffer must be donated"


def test_artifact_parameter_counts():
    arts = aot.artifacts()
    expect = {"logreg_step": 4, "pagerank_step": 3, "wordcount_agg": 2}
    for name, nparams in expect.items():
        text = aot.to_hlo_text(arts[name])
        entry = text[text.index("ENTRY"):]
        body = entry[: entry.index("ROOT")]
        found = body.count("parameter(")
        assert found == nparams, f"{name}: {found} params, expected {nparams}"
