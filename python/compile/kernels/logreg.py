"""L1 Pallas kernel: tiled logistic-regression gradient.

Computes ``X^T (sigmoid(X w) - y) / n`` with the row dimension tiled into
``BLOCK_ROWS`` panels so each HBM->VMEM block is a (BLOCK_ROWS, d) matmul
panel feeding the MXU, and the (d,)-sized partial gradients accumulate in
the output ref across grid steps. Arbitrary ``n`` is handled by padding in
the wrapper: padded rows carry ``y = sigmoid(0) = 0.5`` so their error term
is exactly zero.

Pallas runs ``interpret=True`` on this image (CPU PJRT cannot execute
Mosaic custom-calls); the BlockSpec structure is what a real TPU would
compile. VMEM estimate per step: BLOCK_ROWS*d + d + BLOCK_ROWS + d floats
(~0.26 MB at 128x512 f32), far under the ~16 MB budget.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _kernel(x_ref, y_ref, w_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...]
    y = y_ref[...]
    w = w_ref[...]
    z = x @ w
    err = 1.0 / (1.0 + jnp.exp(-z)) - y
    part = x.T @ err

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


@functools.partial(jax.jit, static_argnames=())
def logreg_grad(w, x, y):
    """Pallas-tiled gradient; matches ``ref.logreg_grad`` exactly.

    w: (d,) f32; x: (n, d) f32; y: (n,) f32 in [0, 1].
    """
    n, d = x.shape
    padded = pl.cdiv(n, BLOCK_ROWS) * BLOCK_ROWS
    if padded != n:
        x = jnp.pad(x, ((0, padded - n), (0, 0)))
        # sigmoid(0 . w) = 0.5 -> err = 0 for padding rows.
        y = jnp.pad(y, (0, padded - n), constant_values=0.5)
    grid = padded // BLOCK_ROWS
    out = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), x.dtype),
        interpret=True,
    )(x, y, w)
    return out / n
