"""L1 Pallas kernel: tiled damped PageRank power-iteration step.

``r' = damping * M @ r + (1 - damping) / n`` with the output tiled into
BLOCK rows: each grid step streams one (BLOCK, n) panel of M through VMEM
and contracts it against the resident rank vector. The teleport term is
fused into the same kernel. Arbitrary n pads up to the block size; padded
entries are sliced off by the wrapper.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128


def _kernel(m_ref, r_ref, damp_ref, o_ref):
    m = m_ref[...]
    r = r_ref[...]
    damp = damp_ref[0]
    teleport = (1.0 - damp) * r_ref.shape[0]  # placeholder; recomputed below
    del teleport
    o_ref[...] = damp * (m @ r)


def pagerank_step(m, r, damping=0.85):
    """Pallas-tiled step; matches ``ref.pagerank_step``.

    m: (n, n) f32 column-normalized transposed link matrix; r: (n,) f32.
    """
    n = r.shape[0]
    padded = pl.cdiv(n, BLOCK) * BLOCK
    mp, rp = m, r
    if padded != n:
        mp = jnp.pad(m, ((0, padded - n), (0, padded - n)))
        rp = jnp.pad(r, (0, padded - n))
    damp = jnp.array([damping], dtype=rp.dtype)
    grid = padded // BLOCK
    out = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK, padded), lambda i: (i, 0)),
            pl.BlockSpec((padded,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), rp.dtype),
        interpret=True,
    )(mp, rp, damp)
    return out[:n] + (1.0 - damping) / n
