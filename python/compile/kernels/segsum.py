"""L1 Pallas kernel: segment sum as a one-hot matmul.

``out[k, v] = sum_i onehot[i, k] * values[i, v]`` — the WordCount /
TPC-H-Q3 group-by expressed as a matmul so the reduction runs on the MXU
systolic array instead of a scatter (DESIGN.md Hardware-Adaptation). The
row dimension is tiled; per-step partials accumulate into the (k, v)
output resident in VMEM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _kernel(h_ref, v_ref, o_ref):
    i = pl.program_id(0)
    part = h_ref[...].T @ v_ref[...]

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part


def segsum(onehot, values):
    """Pallas-tiled segment sum; matches ``ref.segsum``.

    onehot: (n, k) f32 indicator matrix; values: (n, v) f32.
    """
    n, k = onehot.shape
    v = values.shape[1]
    padded = pl.cdiv(n, BLOCK_ROWS) * BLOCK_ROWS
    h, val = onehot, values
    if padded != n:
        h = jnp.pad(onehot, ((0, padded - n), (0, 0)))
        val = jnp.pad(values, ((0, padded - n), (0, 0)))
    grid = padded // BLOCK_ROWS
    return pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, k), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, v), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((k, v), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, v), values.dtype),
        interpret=True,
    )(h, val)
