"""Pallas kernels (L1) and their pure-jnp oracles (``ref``)."""

from . import logreg, pagerank, ref, segsum  # noqa: F401
