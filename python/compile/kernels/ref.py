"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must match its oracle to float tolerance
across the shape/dtype sweep in ``python/tests``. The oracles are also the
semantic documentation: each corresponds to the inner loop of one paper
workload (§6.1 of the HOUTU paper).
"""

import jax.numpy as jnp


def sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


def logreg_grad(w, x, y):
    """Gradient of mean logistic loss: X^T (sigmoid(Xw) - y) / n.

    The per-partition computation of an Iterative-ML task: each task owns a
    shard of (x, y) and emits a gradient that the collect stage averages.
    """
    n = x.shape[0]
    err = sigmoid(x @ w) - y
    return x.T @ err / n


def logreg_loss(w, x, y):
    """Mean logistic loss (for the e2e loss curve)."""
    logits = x @ w
    return jnp.mean(jnp.logaddexp(0.0, logits) - y * logits)


def pagerank_step(m, r, damping=0.85):
    """One damped power iteration: r' = d * M @ r + (1 - d) / n.

    ``m`` is the column-normalized link matrix transposed so the step is a
    plain dense matvec — a PageRank task's per-partition compute.
    """
    n = r.shape[0]
    return damping * (m @ r) + (1.0 - damping) / n


def segsum(onehot, values):
    """Segment sum as a matmul: out[k] = sum_i onehot[i, k] * values[i].

    The group-by/reduce at the heart of WordCount and the TPC-H Q3
    aggregation, expressed as a one-hot matmul so it maps onto the MXU
    (DESIGN.md section Hardware-Adaptation).
    """
    return onehot.T @ values
