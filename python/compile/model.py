"""L2 — the JAX compute graphs tasks execute, calling the L1 kernels.

Three entry points, one per real-compute workload in the coordinator:

* ``logreg_train_step`` — full Iterative-ML step: Pallas gradient +
  SGD update + loss (donated weight buffer; one fused HLO).
* ``pagerank_iteration`` — one damped power iteration + residual.
* ``wordcount_agg`` — segment-sum aggregation of token counts.

``aot.py`` lowers each once to HLO *text* in ``artifacts/``; the rust
runtime loads and executes them via PJRT. Python never runs at request
time.
"""

import jax
import jax.numpy as jnp

from .kernels import logreg, pagerank, segsum
from .kernels import ref


def logreg_train_step(w, x, y, lr):
    """One SGD step on mean logistic loss. Returns (w', loss).

    The gradient goes through the Pallas kernel; the loss through jnp
    (cheap, fuses into the same HLO module).
    """
    grad = logreg.logreg_grad(w, x, y)
    loss = ref.logreg_loss(w, x, y)
    return w - lr * grad, loss


def pagerank_iteration(m, r, damping):
    """One PageRank step. Returns (r', l1_residual)."""
    r2 = pagerank.pagerank_step(m, r, damping)
    resid = jnp.sum(jnp.abs(r2 - r))
    return r2, resid


def wordcount_agg(onehot, values):
    """Group-by/sum of per-token value rows. Returns (k, v) totals."""
    return segsum.segsum(onehot, values)
