"""AOT bridge: lower the L2 graphs to HLO *text* artifacts.

HLO text (NOT ``lowered.compiler_ir('hlo').as_serialized_hlo_module_proto()``)
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the image's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser on the rust side reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Shapes are fixed at export (PJRT compiles per shape): the rust runtime
pads its batches to these shapes.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Export shapes (rust side pads to these; keep in sync with
# rust/src/runtime/mod.rs SHAPES).
LOGREG_N, LOGREG_D = 1024, 64
PAGERANK_N = 256
SEG_N, SEG_K, SEG_V = 1024, 64, 4


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifacts():
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    return {
        "logreg_step": jax.jit(model.logreg_train_step, donate_argnums=(0,)).lower(
            spec((LOGREG_D,), f32),
            spec((LOGREG_N, LOGREG_D), f32),
            spec((LOGREG_N,), f32),
            spec((), f32),
        ),
        "pagerank_step": jax.jit(model.pagerank_iteration).lower(
            spec((PAGERANK_N, PAGERANK_N), f32),
            spec((PAGERANK_N,), f32),
            spec((), f32),
        ),
        "wordcount_agg": jax.jit(model.wordcount_agg).lower(
            spec((SEG_N, SEG_K), f32),
            spec((SEG_N, SEG_V), f32),
        ),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for name, lowered in artifacts().items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")


if __name__ == "__main__":
    main()
