#!/usr/bin/env bash
# Tier-1 verify in one command: build everything (lib, bin, tests,
# benches, examples), fail on rustdoc rot (docs are CI-gated: broken
# intra-doc links or bad doc syntax exit non-zero), run the full test
# suite, then a smoke scenario campaign through the real CLI with a
# report export whose round-trip the CLI asserts (it re-reads and
# re-parses the file, exiting non-zero on any mismatch) — so the export
# path stays wired — then the same smoke campaign on the sharded queue
# engine with a digest diff against the sequential report (the
# parallel-DES determinism gate at the CLI level), then the same smoke
# campaign on the World-as-parts ShardedSim engine serial and at 4
# threads with an internal digest diff (the threaded-determinism gate;
# the bench harness additionally times that pair as the
# campaign-smoke-parts / campaign-smoke-threaded rows, which land in
# BENCH_history.jsonl like every other row), then the open-loop
# load smoke ramp (`houtu load --smoke`) on both engines with its
# round-trip-verified report's digest and knee diffed (the load
# determinism gate), then a seeded
# chaos-fuzz smoke batch (any invariant violation is shrunk to a minimal
# repro TOML and fails the build), and finally the perf harness:
# `bench --smoke` times every workload — including the per-strategy
# bid-churn cost rows, the typed-vs-boxed dispatch pair and the
# sharded-vs-sequential multi-DC pair — writes BENCH_sim.json (whose
# util::json round-trip the CLI asserts), appends one trajectory row to
# BENCH_history.jsonl and gates against BENCH_baseline.json: a workload
# that regresses beyond the committed baseline's noise band exits
# non-zero. The smoke campaign additionally records its executed event
# stream and replays it through `houtu replay`, so persistent
# determinism (not just in-process digests) is CI-gated.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release --all-targets
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
cargo test -q
cargo run --release --quiet -- campaign --smoke --report /tmp/smoke.json --record /tmp/smoke-events.log
cargo run --release --quiet -- replay /tmp/smoke-events.log
cargo run --release --quiet -- campaign --smoke --shards 4 --report /tmp/smoke-sharded.json

# Engine-invariance gate: the sharded campaign must reproduce the
# sequential per-run digests bit-for-bit.
grep -o '"digest": "[0-9a-f]*"' /tmp/smoke.json > /tmp/smoke-digests.txt
grep -o '"digest": "[0-9a-f]*"' /tmp/smoke-sharded.json > /tmp/smoke-sharded-digests.txt
if ! diff -u /tmp/smoke-digests.txt /tmp/smoke-sharded-digests.txt; then
  echo "ci.sh: sharded campaign digests diverged from the sequential engine" >&2
  exit 1
fi
echo "ci.sh: sharded campaign digests match the sequential engine"

# Open-loop load smoke: a tiny fixed-seed ramp through the real CLI with
# a round-trip-verified report, run on both queue engines — the digest
# and the reported knee must be engine-invariant (the load determinism
# gate; same shape as the campaign gate above).
cargo run --release --quiet -- load --smoke --seed 42 --report /tmp/load-smoke.json
cargo run --release --quiet -- load --smoke --seed 42 --shards 4 --report /tmp/load-smoke-sharded.json
for f in /tmp/load-smoke.json /tmp/load-smoke-sharded.json; do
  grep -o '"digest": "[0-9a-f]*"' "$f"
  grep '"knee"' "$f"
done > /tmp/load-digests.txt
head -2 /tmp/load-digests.txt > /tmp/load-seq.txt
tail -2 /tmp/load-digests.txt > /tmp/load-sharded.txt
if ! diff -u /tmp/load-seq.txt /tmp/load-sharded.txt; then
  echo "ci.sh: sharded load digest/knee diverged from the sequential engine" >&2
  exit 1
fi
echo "ci.sh: load smoke digest and knee match across engines"

# World-as-parts engine gate: the same smoke campaign on the ShardedSim
# parts model, serial vs 4 worker threads. The parts engine has its own
# digest domain (a differently-factored state model), so the diff is
# internal to the engine: the 4-thread run must reproduce the serial
# parts digests bit-for-bit (the threaded-determinism gate at the CLI
# level; the in-process walls live in tests/golden_digests.rs and
# tests/part_world.rs).
cargo run --release --quiet -- campaign --smoke --engine sharded-sim --threads 1 --report /tmp/smoke-parts.json
cargo run --release --quiet -- campaign --smoke --engine sharded-sim --threads 4 --report /tmp/smoke-parts-threaded.json
grep -o '"digest": "[0-9a-f]*"' /tmp/smoke-parts.json > /tmp/smoke-parts-digests.txt
grep -o '"digest": "[0-9a-f]*"' /tmp/smoke-parts-threaded.json > /tmp/smoke-parts-threaded-digests.txt
if ! diff -u /tmp/smoke-parts-digests.txt /tmp/smoke-parts-threaded-digests.txt; then
  echo "ci.sh: threaded parts-engine digests diverged from the serial parts run" >&2
  exit 1
fi
echo "ci.sh: parts-engine campaign digests are thread-count invariant"

# Generated-topology gate: the same smoke campaign rebased onto a
# 64-DC generated world (`--topology`, docs/SCALE.md) with a 4-DC
# exact tier, serial vs 4 threads — planet-scale worlds must be as
# deterministic as the hand-written 4-DC ones (the in-process walls
# live in tests/planet.rs).
cargo run --release --quiet -- campaign --smoke --topology generated:64,4,7 --set topology.exact_dcs=4 --engine sharded-sim --threads 1 --report /tmp/smoke-planet.json
cargo run --release --quiet -- campaign --smoke --topology generated:64,4,7 --set topology.exact_dcs=4 --engine sharded-sim --threads 4 --report /tmp/smoke-planet-threaded.json
grep -o '"digest": "[0-9a-f]*"' /tmp/smoke-planet.json > /tmp/smoke-planet-digests.txt
grep -o '"digest": "[0-9a-f]*"' /tmp/smoke-planet-threaded.json > /tmp/smoke-planet-threaded-digests.txt
if ! diff -u /tmp/smoke-planet-digests.txt /tmp/smoke-planet-threaded-digests.txt; then
  echo "ci.sh: 64-DC generated-world digests diverged across thread counts" >&2
  exit 1
fi
echo "ci.sh: 64-DC generated-world campaign digests are thread-count invariant"

cargo run --release --quiet -- fuzz --cases 8 --seed 1 --repro /tmp/fuzz-repro.toml
cargo run --release --quiet -- bench --smoke --report BENCH_sim.json --history BENCH_history.jsonl --compare BENCH_baseline.json

# The committed baseline starts life as a bootstrap (all-zero throughput
# rows, which --compare skips). Promote the first green measured run so
# later runs gate against real numbers; refresh intentionally by
# re-copying after a known-good perf change.
if ! grep -q '"events_per_sec": [1-9]' BENCH_baseline.json; then
  cp BENCH_sim.json BENCH_baseline.json
  echo "ci.sh: promoted BENCH_sim.json to BENCH_baseline.json (bootstrap)"
fi

echo "ci.sh: all green"
