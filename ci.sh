#!/usr/bin/env bash
# Tier-1 verify in one command: build, full test suite, then a smoke
# scenario campaign through the real CLI (seconds, not minutes).
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q
cargo run --release --quiet -- campaign --smoke
echo "ci.sh: all green"
