#!/usr/bin/env bash
# Tier-1 verify in one command: build everything (lib, bin, tests,
# benches, examples), fail on rustdoc rot (docs are CI-gated: broken
# intra-doc links or bad doc syntax exit non-zero), run the full test
# suite, then a smoke scenario campaign through the real CLI with a
# report export whose round-trip the CLI asserts (it re-reads and
# re-parses the file, exiting non-zero on any mismatch) — so the export
# path stays wired — then a seeded chaos-fuzz smoke batch (any invariant
# violation is shrunk to a minimal repro TOML and fails the build), and
# finally the perf harness: `bench --smoke` times every workload —
# including the per-strategy bid-churn cost rows — and writes
# BENCH_sim.json, whose util::json round-trip the CLI asserts — every
# run extends the perf trajectory.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release --all-targets
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
cargo test -q
cargo run --release --quiet -- campaign --smoke --report /tmp/smoke.json
cargo run --release --quiet -- fuzz --cases 8 --seed 1 --repro /tmp/fuzz-repro.toml
cargo run --release --quiet -- bench --smoke --report BENCH_sim.json
echo "ci.sh: all green"
