//! Hot-path microbenchmarks (L3 perf deliverable, DESIGN.md §Perf).
//!
//! No criterion in the offline image, so this is a plain timing harness:
//! warm up, run N iterations, report ns/op and ops/s. Targets:
//! * Parades `on_update` — called on every container heartbeat;
//! * Af step — every sub-job every period;
//! * fair-scheduler allocation — every master every period;
//! * zk write+watch — every task completion;
//! * DES event dispatch — everything rides on it;
//! * whole Fig-8 trace — the end-to-end number.

use std::time::Instant;

use houtu::cloud::InstanceClass;
use houtu::cluster::Cluster;
use houtu::config::{Config, Deployment};
use houtu::consensus::ZkEnsemble;
use houtu::ids::*;
use houtu::jm::{af::AfState, af::PeriodFeedback, on_update, ContainerView, ParadesParams, WaitingTask};
use houtu::master::Master;
use houtu::sim::Sim;
use houtu::util::Pcg;

fn bench(name: &str, iters: u64, mut f: impl FnMut()) {
    // Warm-up.
    for _ in 0..iters / 10 + 1 {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let dt = t0.elapsed();
    let ns = dt.as_nanos() as f64 / iters as f64;
    println!("{name:<38} {ns:>12.0} ns/op {:>14.0} ops/s", 1e9 / ns);
}

fn parades_queue(rng: &mut Pcg, len: usize) -> Vec<WaitingTask> {
    (0..len)
        .map(|i| {
            let pref = NodeId { dc: DcId(rng.index(4)), idx: rng.index(4) };
            WaitingTask {
                id: TaskId { job: JobId(1), stage: StageId(0), index: i as u32 },
                r: rng.uniform(0.1, 0.7),
                p: rng.uniform(5.0, 60.0),
                input_bytes: 1 << 27,
                pref_node: Some(pref),
                pref_rack: Some((pref.dc, pref.idx % 2)),
                wait: rng.uniform(0.0, 30.0),
            }
        })
        .collect()
}

fn main() {
    let params = ParadesParams { delta: 0.7, tau: 0.5 };
    let mut rng = Pcg::seeded(1);

    // Parades on_update over a 64-task queue (worst realistic backlog).
    let base = parades_queue(&mut rng, 64);
    let view = ContainerView {
        id: ContainerId(1),
        node: NodeId { dc: DcId(0), idx: 0 },
        rack: 0,
        free: 1.0,
    };
    bench("parades::on_update (64-task queue)", 200_000, || {
        let mut q = base.clone();
        let picks = on_update(&mut q, view, params, false);
        std::hint::black_box(picks);
    });

    // Af step.
    let mut af = AfState::default();
    bench("af::step", 2_000_000, || {
        let d = af.step(
            PeriodFeedback { utilization: 0.8, allocation: 4, had_waiting_tasks: true },
            0.7,
            1.5,
            16,
        );
        std::hint::black_box(d);
    });

    // Fair-scheduler allocation: 8 sub-jobs over 64 containers.
    bench("master::allocate (8 jobs, 64 slots)", 20_000, || {
        let mut cluster =
            Cluster::build(&["A".into()], 16, 4, 2, |_, _| InstanceClass::OnDemand);
        let mut m = Master::new(DcId(0));
        for j in 0..8 {
            let jm = JmId { job: JobId(j), dc: DcId(0) };
            m.register(jm);
            m.set_desire(jm, 12);
        }
        std::hint::black_box(m.allocate(&mut cluster));
    });

    // zk write + watch fire.
    let mut zk = ZkEnsemble::new(4);
    let s1 = zk.connect(DcId(0));
    let s2 = zk.connect(DcId(1));
    zk.create(s1, "/bench", vec![0; 256], false, false).unwrap();
    bench("zk set_data + watch", 500_000, || {
        zk.watch(s2, "/bench", houtu::consensus::WatchKind::Data);
        std::hint::black_box(zk.set_data("/bench", vec![1; 256]).unwrap());
    });

    // DES event dispatch.
    bench("sim event schedule+dispatch", 50, || {
        let mut sim = Sim::new(0u64);
        for t in 0..100_000u64 {
            sim.schedule_at(t, |s| s.state += 1);
        }
        sim.run_to_completion();
        assert_eq!(sim.state, 100_000);
    });
    println!("(sim bench is per 100k events — divide by 1e5 for per-event)");

    // End-to-end: the full Fig-8 trace on HOUTU.
    let cfg = Config::default();
    let t0 = Instant::now();
    let w = houtu::deploy::run_trace_experiment(&cfg, Deployment::Houtu);
    let dt = t0.elapsed();
    println!(
        "end-to-end houtu trace ({} jobs, {:.0}s simulated): {:.2?} wall",
        cfg.workload.num_jobs,
        w.metrics.makespan(),
        dt
    );
}
