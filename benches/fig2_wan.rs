//! Regenerates Fig 2: the (mean, std) Mbps WAN bandwidth matrix, measured
//! iperf-style against the AR(1) fabric (3 rounds x 5 min).
fn main() {
    let cfg = houtu::config::Config::default();
    print!("{}", houtu::exp::fig2_wan(&cfg));
}
