//! Ablation sweeps over the design choices DESIGN.md calls out:
//! τ (delay-scheduling patience), ρ (Af growth factor), δ (utilization
//! threshold), L (period length), FIFO-vs-fair for static baselines, and
//! the §2.3 extension: reliable (On-demand) JM hosts in a spot fleet.

use houtu::config::{Config, Deployment};
use houtu::deploy::run_trace_experiment;

fn run(cfg: &Config) -> (f64, f64, f64) {
    let w = run_trace_experiment(cfg, cfg.deployment);
    (w.metrics.avg_jrt(), w.metrics.makespan(), {
        w.wan.stats.cross_dc_total_bytes() as f64 / (1 << 30) as f64
    })
}

fn main() {
    let base = Config::default();

    println!("--- τ sweep (Parades patience; threshold = τ·p / 2τ·p) ---");
    println!("{:>6} {:>12} {:>12} {:>14}", "tau", "avg JRT (s)", "makespan", "cross-DC GB");
    for tau in [0.1, 0.25, 0.5, 1.0, 2.0] {
        let mut c = base.clone();
        c.scheduler.tau = tau;
        let (jrt, mk, gb) = run(&c);
        println!("{tau:>6} {jrt:>12.0} {mk:>12.0} {gb:>14.2}");
    }

    println!("\n--- ρ sweep (Af growth factor) ---");
    println!("{:>6} {:>12} {:>12}", "rho", "avg JRT (s)", "makespan");
    for rho in [1.2, 1.5, 2.0, 3.0] {
        let mut c = base.clone();
        c.scheduler.rho = rho;
        let (jrt, mk, _) = run(&c);
        println!("{rho:>6} {jrt:>12.0} {mk:>12.0}");
    }

    println!("\n--- δ sweep (Af utilization threshold) ---");
    println!("{:>6} {:>12} {:>12}", "delta", "avg JRT (s)", "makespan");
    for delta in [0.3, 0.5, 0.7, 0.9] {
        let mut c = base.clone();
        c.scheduler.delta = delta;
        let (jrt, mk, _) = run(&c);
        println!("{delta:>6} {jrt:>12.0} {mk:>12.0}");
    }

    println!("\n--- L sweep (scheduling period, seconds) ---");
    println!("{:>6} {:>12} {:>12}", "L", "avg JRT (s)", "makespan");
    for l in [2.0, 5.0, 10.0, 20.0] {
        let mut c = base.clone();
        c.scheduler.period_l_secs = l;
        let (jrt, mk, _) = run(&c);
        println!("{l:>6} {jrt:>12.0} {mk:>12.0}");
    }

    println!("\n--- static-baseline queue policy (cent-stat) ---");
    for (label, fifo) in [("FIFO (stock YARN)", true), ("fair-share", false)] {
        let mut c = base.clone();
        c.deployment = Deployment::CentStat;
        c.scheduler.static_fifo = fifo;
        let (jrt, mk, _) = run(&c);
        println!("{label:<22} avg JRT {jrt:>5.0}s  makespan {mk:>5.0}s");
    }

    println!("\n--- straggler mitigation (25% of tasks 6x slow) ---");
    for (label, spec) in [("speculation on", true), ("speculation off", false)] {
        let mut c = base.clone();
        c.workload.straggler_prob = 0.25;
        c.workload.straggler_factor = 6.0;
        c.failures.speculation = spec;
        let w = run_trace_experiment(&c, Deployment::Houtu);
        let relaunches: u32 = w.jobs.values().map(|rt| rt.speculative_relaunches).sum();
        println!(
            "{label:<18} avg JRT {:>5.0}s  makespan {:>5.0}s  relaunches {relaunches}",
            w.metrics.avg_jrt(),
            w.metrics.makespan()
        );
    }

    println!("\n--- §2.3 extension: reliable JM hosts under spot chaos ---");
    for (label, reliable) in [("all-spot workers", false), ("on-demand JM hosts", true)] {
        let mut c = base.clone();
        c.workload.num_jobs = 8;
        c.cloud.revocations = true;
        c.cloud.spot_volatility = 0.6;
        c.cloud.market_period_secs = 60.0;
        c.cloud.bid_multiplier = 1.3;
        c.cloud.reliable_jm_hosts = reliable;
        let w = run_trace_experiment(&c, Deployment::Houtu);
        println!(
            "{label:<22} avg JRT {:>5.0}s  JM recoveries {:>2}  machine ${:.2}",
            w.metrics.avg_jrt(),
            w.metrics.recovery_intervals_secs.len(),
            w.cost.machine_usd,
        );
    }
}
