//! Regenerates Fig 12: intermediate-info sizes per workload (large
//! inputs) and the time cost of HOUTU's mechanisms.
fn main() {
    let cfg = houtu::config::Config::default();
    print!("{}", houtu::exp::fig12_overhead(&cfg));
}
