//! Regenerates Fig 3: the Reserved / On-demand / Spot price table.
fn main() {
    print!("{}", houtu::exp::fig3_table());
    print!("{}", houtu::exp::fig7_table()); // Fig 7 rides along (static table)
}
