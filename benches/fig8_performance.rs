//! Regenerates Fig 8: JRT CDF + avg JRT & makespan across the four
//! deployments on the online trace.
fn main() {
    let cfg = houtu::config::Config::default();
    let t0 = std::time::Instant::now();
    let (report, _) = houtu::exp::fig8_performance(&cfg);
    print!("{report}");
    println!("\n[bench] four deployments simulated in {:.2?}", t0.elapsed());
}
