//! Theorem 1 empirical check: the achieved makespan stays within a small
//! constant of the work/span lower bound, across several seeds.
fn main() {
    let mut cfg = houtu::config::Config::default();
    let mut worst: f64 = 0.0;
    for seed in [42, 43, 44, 45] {
        cfg.seed = seed;
        let (report, ratio) = houtu::exp::theorem1_bound(&cfg);
        print!("[seed {seed}] {report}");
        worst = worst.max(ratio);
    }
    println!("worst ratio over seeds: {worst:.2}x");
    assert!(worst < 12.0, "competitive ratio blew up: {worst}");
}
