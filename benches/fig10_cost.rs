//! Regenerates Fig 10: normalized machine + communication cost.
fn main() {
    let cfg = houtu::config::Config::default();
    let (_, results) = houtu::exp::fig8_performance(&cfg);
    print!("{}", houtu::exp::fig10_cost(&results));
}
