//! Regenerates Fig 9: cumulative running tasks under injected load,
//! with and without work stealing.
fn main() {
    let cfg = houtu::config::Config::default();
    let (report, _) = houtu::exp::fig9_stealing(&cfg);
    print!("{report}");
}
