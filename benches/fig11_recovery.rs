//! Regenerates Fig 11: containers over time through pJM / sJM / 
//! centralized-JM failures at t=70 s, plus the resulting JRTs.
fn main() {
    let cfg = houtu::config::Config::default();
    print!("{}", houtu::exp::fig11_recovery(&cfg));
}
